"""Concurrency stress for the chunked read path: ChunkCache under thread
hammering, and concurrent multi-codec gathers through the DecodePipeline.

The cache is the one shared mutable structure on the read path (the pipeline
itself keeps per-call state), so it gets a dedicated torture test: 8+
threads mixing get/put/invalidate/clear must never produce torn entries,
must respect the LRU byte bound, and must keep the hit/miss counters
exactly consistent (every get is either a hit or a miss — the counters are
taken under the entry lock, so a race would be a real bug, not noise).
"""

import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.aggregation import AggregationConfig, ChunkPipeline
from repro.core.container import ChunkCache, TH5File

N_THREADS = 8


def _signed_array(key_id: int, rows: int = 16) -> np.ndarray:
    """An array whose every element encodes its key — any mixed-up or torn
    entry is detectable from the payload alone."""
    return np.full((rows, 4), float(key_id), np.float32)


# -- pure cache hammering ------------------------------------------------------


def test_cache_hammer_no_torn_entries_and_consistent_counters():
    cache = ChunkCache(capacity_bytes=40 * _signed_array(0).nbytes)
    n_keys = 128
    ops_per_thread = 2000
    gets = [0] * N_THREADS
    errors: list[str] = []
    start = threading.Barrier(N_THREADS)

    def worker(tid: int) -> None:
        rng = np.random.default_rng(tid)
        start.wait()
        for i in range(ops_per_thread):
            k = int(rng.integers(0, n_keys))
            key = (f"/ds{k % 4}", k)
            op = int(rng.integers(0, 10))
            if op < 6:  # 60% get
                got = cache.get(key)
                gets[tid] += 1
                if got is not None and not np.all(got == float(k)):
                    errors.append(f"torn entry for {key}")
            elif op < 9:  # 30% put
                cache.put(key, _signed_array(k))
            elif i % 97 == 0:  # rare full clear
                cache.clear()
            else:  # invalidate one dataset's entries
                cache.invalidate(f"/ds{k % 4}")

    with ThreadPoolExecutor(max_workers=N_THREADS) as pool:
        for f in [pool.submit(worker, t) for t in range(N_THREADS)]:
            f.result()

    assert not errors, errors[:5]
    s = cache.stats()
    assert s["hits"] + s["misses"] == sum(gets)  # counters race-free
    assert s["bytes"] <= cache.capacity_bytes  # LRU byte bound held
    assert s["bytes"] == sum(e.nbytes for e in cache._entries.values())


def test_cache_lru_bound_under_concurrent_oversized_puts():
    """Puts racing evictions: the byte accounting must stay exact (no
    drift), entries must stay ≤ capacity at every sample point."""
    entry = _signed_array(0)
    cache = ChunkCache(capacity_bytes=5 * entry.nbytes)
    stop = threading.Event()
    violations: list[int] = []

    def sampler() -> None:
        while not stop.is_set():
            b = cache.stats()["bytes"]
            if b > cache.capacity_bytes:
                violations.append(b)

    def putter(tid: int) -> None:
        for i in range(3000):
            cache.put((f"/d{tid}", i), _signed_array(i))

    t = threading.Thread(target=sampler)
    t.start()
    with ThreadPoolExecutor(max_workers=N_THREADS) as pool:
        for f in [pool.submit(putter, t_) for t_ in range(N_THREADS)]:
            f.result()
    stop.set()
    t.join()
    assert not violations
    s = cache.stats()
    assert s["bytes"] <= cache.capacity_bytes
    assert s["evictions"] > 0  # the bound was actually exercised


# -- concurrent reads through the DecodePipeline -------------------------------


@pytest.fixture(scope="module")
def mixed_codec_file(tmp_path_factory):
    """One TH5 file with four chunked datasets across all codec families
    (plus a contiguous control), shared read-only by the stress tests."""
    path = str(tmp_path_factory.mktemp("stress") / "mixed.th5")
    rng = np.random.default_rng(42)
    datasets = {
        "/none": (rng.integers(0, 255, (512, 16), dtype=np.uint8), "none"),
        "/zlib": ((rng.integers(0, 64, (512, 16)) / 64).astype(np.float32), "zlib"),
        "/shuf": ((rng.integers(0, 64, (512, 16)) / 64).astype(np.float32), "shuffle+zlib"),
        "/mixed": (  # per-chunk codec fallback: half none, half zlib
            np.concatenate(
                [
                    rng.integers(0, 2**63, (64, 2), dtype=np.int64) if i % 2
                    else np.zeros((64, 2), np.int64)
                    for i in range(8)
                ]
            ),
            "zlib",
        ),
    }
    with TH5File.create(path) as f:
        for name, (data, codec) in datasets.items():
            meta = f.create_chunked_dataset(name, data.shape, data.dtype, 64, codec)
            with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
                pipe.write(meta, data)
        f.commit()
    return path, {k: v[0] for k, v in datasets.items()}


def test_concurrent_multi_codec_reads_no_torn_rows(mixed_codec_file):
    """8+ threads gather random row ranges / scatter indices / full reads
    over mixed codecs concurrently, racing cache evictions and explicit
    invalidations — every result must be bit-exact (no torn rows, no
    cross-chunk mixups)."""
    path, datasets = mixed_codec_file
    with TH5File.open(path) as f:
        f.chunk_cache.capacity_bytes = 3 * 64 * 16 * 4  # force eviction races
        names = list(datasets)
        errors: list[str] = []
        start = threading.Barrier(N_THREADS)

        def reader(tid: int) -> None:
            rng = np.random.default_rng(100 + tid)
            start.wait()
            for i in range(60):
                name = names[int(rng.integers(0, len(names)))]
                data = datasets[name]
                mode = int(rng.integers(0, 4))
                try:
                    if mode == 0:  # contiguous range, arbitrary chunk straddle
                        lo = int(rng.integers(0, data.shape[0] - 1))
                        n = int(rng.integers(1, data.shape[0] - lo + 1))
                        got = f.read_rows(name, lo, n)
                        want = data[lo : lo + n]
                    elif mode == 1:  # scatter gather
                        idx = rng.integers(0, data.shape[0], 32)
                        got = f.read_row_indices(name, idx)
                        want = data[idx]
                    elif mode == 2:  # full read (pipelined cold path)
                        got = f.read(name, verify=bool(i % 2))
                        want = data
                    else:  # racing invalidation — legal any time
                        f.chunk_cache.invalidate(name)
                        continue
                    if not np.array_equal(got, want):
                        errors.append(f"torn read: {name} mode={mode} tid={tid}")
                except Exception as e:  # pragma: no cover - failure reporting
                    errors.append(f"{name} mode={mode} tid={tid}: {type(e).__name__}: {e}")

        with ThreadPoolExecutor(max_workers=N_THREADS) as pool:
            for fut in [pool.submit(reader, t) for t in range(N_THREADS)]:
                fut.result()
        assert not errors, errors[:5]
        s = f.chunk_cache.stats()
        assert s["hits"] + s["misses"] > 0
        assert s["bytes"] <= f.chunk_cache.capacity_bytes
        # decode accounting survived the stampede: cumulative read stats
        # saw real pipeline work and the per-read slot is populated
        assert f.read_stats is not None and f.read_stats.n_chunks > 0
        assert f.last_read_stats is not None


def test_concurrent_window_prefetchers_share_one_pipeline(mixed_codec_file):
    """Several WindowPrefetchers over the same file (the multi-client
    playback scenario) drive the shared DecodePipeline + cache from their
    worker threads without corruption."""
    from repro.core.sliding_window import WindowPrefetcher

    path, datasets = mixed_codec_file
    with TH5File.open(path) as f:
        windows = [range(lo, lo + 64, 2) for lo in range(0, 448, 32)]

        def playback(name: str) -> int:
            data = datasets[name]
            with WindowPrefetcher(f, name) as pf:
                for rows, got in zip(windows, pf.iter_windows(windows)):
                    np.testing.assert_array_equal(got, data[list(rows)])
            return 1

        with ThreadPoolExecutor(max_workers=4) as pool:
            futs = [pool.submit(playback, n) for n in ("/zlib", "/shuf", "/none", "/mixed")]
            assert sum(fut.result() for fut in futs) == 4
        stats = f.read_stats
        assert stats is not None and stats.n_chunks >= 8