"""TH5 container: roundtrip, self-description, shadow paging, crash safety."""

import os
import threading

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.container import (
    SUPERBLOCK_SIZE,
    CorruptFileError,
    TH5Error,
    TH5File,
)

DTYPES = ["<f4", "<f8", "<i4", "<i8", "<u8", "<u1", ">f4", ">i4", "<f2"]


@pytest.fixture
def path(tmp_path):
    return str(tmp_path / "t.th5")


def test_create_open_roundtrip(path):
    with TH5File.create(path) as f:
        f.create_group("/common", attrs={"dt": 0.01, "name": "run"})
        d = f.create_dataset("/simulation/s0/x", (4, 3), "<f4", attrs={"k": 1})
        f.write_full(d, np.arange(12, dtype=np.float32).reshape(4, 3))
        f.commit()
    with TH5File.open(path) as f:
        assert f.group_attrs("/common") == {"dt": 0.01, "name": "run"}
        got = f.read("/simulation/s0/x")
        np.testing.assert_array_equal(got, np.arange(12, dtype=np.float32).reshape(4, 3))
        assert f.meta("/simulation/s0/x").attrs == {"k": 1}


@given(
    dtype=st.sampled_from(DTYPES),
    shape=st.lists(st.integers(min_value=0, max_value=17), min_size=0, max_size=3),
)
@settings(max_examples=60, deadline=None)
def test_roundtrip_dtypes_shapes(tmp_path_factory, dtype, shape):
    """Self-description sweep: any dtype/endianness/shape must roundtrip to
    native byte order on read (the paper's HDF5 portability argument)."""
    p = str(tmp_path_factory.mktemp("th5") / "x.th5")
    rng = np.random.default_rng(0)
    dt = np.dtype(dtype)
    n = int(np.prod(shape)) if shape else 1
    if dt.kind == "f":
        arr = rng.standard_normal(n).astype(dt)
    else:
        arr = rng.integers(0, 100, n).astype(dt)
    arr = arr.reshape(shape)
    with TH5File.create(p) as f:
        d = f.create_dataset("/a", shape, dt)
        f.write_full(d, arr, checksum=True)
        f.commit()
    with TH5File.open(p) as f:
        got = f.read("/a", verify=True)
        assert got.dtype.isnative
        np.testing.assert_array_equal(got.astype(dt), arr)
    os.unlink(p)


def test_partial_rows_and_indices(path):
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (100, 8), "<i8")
        f.write_full(d, np.arange(800).reshape(100, 8))
        f.commit()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read_rows("/x", 10, 5), np.arange(80, 120).reshape(5, 8))
        idx = [3, 99, 0, 50, 51, 52, 3]
        got = f.read_row_indices("/x", idx)
        want = np.arange(800).reshape(100, 8)[idx]
        np.testing.assert_array_equal(got, want)


def test_shadow_paging_generations(path):
    """Appending a session never disturbs prior data; generation increments."""
    f = TH5File.create(path)
    g0 = f.generation
    d1 = f.create_dataset("/s/one", (4,), "<f4")
    f.write_full(d1, np.ones(4, np.float32))
    g1 = f.commit()
    d2 = f.create_dataset("/s/two", (4,), "<f4")
    f.write_full(d2, 2 * np.ones(4, np.float32))
    g2 = f.commit()
    assert g0 < g1 < g2
    f.close()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/s/one"), np.ones(4, np.float32))
        np.testing.assert_array_equal(f.read("/s/two"), 2 * np.ones(4, np.float32))


def test_crash_before_commit_preserves_previous(path):
    """Torn write: slabs written but no commit → reopen sees the previous
    generation only (the shadow-page crash-consistency claim)."""
    f = TH5File.create(path)
    d1 = f.create_dataset("/s/one", (4,), "<f4")
    f.write_full(d1, np.ones(4, np.float32))
    f.commit()
    # second session writes data but "crashes" before commit
    d2 = f.create_dataset("/s/two", (4,), "<f4")
    f.write_full(d2, 2 * np.ones(4, np.float32))
    os.close(f.fd)  # simulate process death — no commit, no close()
    f._closed = True
    with TH5File.open(path) as g:
        assert g.exists("/s/one")
        assert not g.exists("/s/two")
        np.testing.assert_array_equal(g.read("/s/one"), np.ones(4, np.float32))


def test_corrupt_superblock_detected(path):
    with TH5File.create(path) as f:
        f.commit()
    with open(path, "r+b") as fh:
        fh.seek(8)
        fh.write(b"\xff\xff")
    with pytest.raises(CorruptFileError):
        TH5File.open(path)


def test_payload_checksum_detects_bitrot(path):
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (1024,), "<u1")
        f.write_full(d, np.zeros(1024, np.uint8), checksum=True)
        f.commit()
        off = d.offset
    with open(path, "r+b") as fh:
        fh.seek(off + 100)
        fh.write(b"\x01")
    with TH5File.open(path) as f:
        with pytest.raises(CorruptFileError):
            f.read("/x", verify=True)
        f.read("/x", verify=False)  # unverified read still possible


def test_concurrent_lock_free_slab_writes(path):
    """The paper's core safety claim: disjoint extents need no locking.
    32 writer threads, one extent each, full coverage, no corruption."""
    n_ranks, rows_per, cols = 32, 64, 16
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (n_ranks * rows_per, cols), "<i4")

        def writer(rank):
            data = np.full((rows_per, cols), rank, dtype=np.int32)
            f.write_rows(d, rank * rows_per, data)

        threads = [threading.Thread(target=writer, args=(r,)) for r in range(n_ranks)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        f.commit()
    with TH5File.open(path) as f:
        got = f.read("/x")
        for r in range(n_ranks):
            assert (got[r * rows_per : (r + 1) * rows_per] == r).all()


def test_alignment_of_extents(path):
    with TH5File.create(path, block_size=4096) as f:
        d1 = f.create_dataset("/a", (3,), "<u1")
        d2 = f.create_dataset("/b", (3,), "<u1")
        assert d1.offset % 4096 == 0
        assert d2.offset % 4096 == 0
        assert d1.offset >= SUPERBLOCK_SIZE


def test_write_bounds_checked(path):
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (4,), "<f4")
        with pytest.raises(TH5Error):
            f.write_slab(d, 8, np.zeros(4, np.float32))  # 8+16 > 16


def test_children_listing(path):
    with TH5File.create(path) as f:
        f.create_group("/simulation/step_00000001")
        f.create_group("/simulation/step_00000002")
        f.create_dataset("/simulation/step_00000001/x", (1,), "<f4")
        assert f.children("/simulation") == [
            "/simulation/step_00000001",
            "/simulation/step_00000002",
        ]
        assert "/simulation/step_00000001/x" in f.children("/simulation/step_00000001")


def test_readonly_mode(path):
    with TH5File.create(path) as f:
        f.commit()
    with TH5File.open(path, "r") as f:
        with pytest.raises(TH5Error):
            f.create_group("/g")
