"""TH5 container: roundtrip, self-description, shadow paging, crash safety."""

import os
import threading

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.container import (
    SUPERBLOCK_SIZE,
    CorruptFileError,
    TH5Error,
    TH5File,
)

DTYPES = ["<f4", "<f8", "<i4", "<i8", "<u8", "<u1", ">f4", ">i4", "<f2"]


@pytest.fixture
def path(tmp_path):
    return str(tmp_path / "t.th5")


def test_create_open_roundtrip(path):
    with TH5File.create(path) as f:
        f.create_group("/common", attrs={"dt": 0.01, "name": "run"})
        d = f.create_dataset("/simulation/s0/x", (4, 3), "<f4", attrs={"k": 1})
        f.write_full(d, np.arange(12, dtype=np.float32).reshape(4, 3))
        f.commit()
    with TH5File.open(path) as f:
        assert f.group_attrs("/common") == {"dt": 0.01, "name": "run"}
        got = f.read("/simulation/s0/x")
        np.testing.assert_array_equal(got, np.arange(12, dtype=np.float32).reshape(4, 3))
        assert f.meta("/simulation/s0/x").attrs == {"k": 1}


@given(
    dtype=st.sampled_from(DTYPES),
    shape=st.lists(st.integers(min_value=0, max_value=17), min_size=0, max_size=3),
)
@settings(max_examples=60, deadline=None)
def test_roundtrip_dtypes_shapes(tmp_path_factory, dtype, shape):
    """Self-description sweep: any dtype/endianness/shape must roundtrip to
    native byte order on read (the paper's HDF5 portability argument)."""
    p = str(tmp_path_factory.mktemp("th5") / "x.th5")
    rng = np.random.default_rng(0)
    dt = np.dtype(dtype)
    n = int(np.prod(shape)) if shape else 1
    if dt.kind == "f":
        arr = rng.standard_normal(n).astype(dt)
    else:
        arr = rng.integers(0, 100, n).astype(dt)
    arr = arr.reshape(shape)
    with TH5File.create(p) as f:
        d = f.create_dataset("/a", shape, dt)
        f.write_full(d, arr, checksum=True)
        f.commit()
    with TH5File.open(p) as f:
        got = f.read("/a", verify=True)
        assert got.dtype.isnative
        np.testing.assert_array_equal(got.astype(dt), arr)
    os.unlink(p)


def test_partial_rows_and_indices(path):
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (100, 8), "<i8")
        f.write_full(d, np.arange(800).reshape(100, 8))
        f.commit()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read_rows("/x", 10, 5), np.arange(80, 120).reshape(5, 8))
        idx = [3, 99, 0, 50, 51, 52, 3]
        got = f.read_row_indices("/x", idx)
        want = np.arange(800).reshape(100, 8)[idx]
        np.testing.assert_array_equal(got, want)


def test_shadow_paging_generations(path):
    """Appending a session never disturbs prior data; generation increments."""
    f = TH5File.create(path)
    g0 = f.generation
    d1 = f.create_dataset("/s/one", (4,), "<f4")
    f.write_full(d1, np.ones(4, np.float32))
    g1 = f.commit()
    d2 = f.create_dataset("/s/two", (4,), "<f4")
    f.write_full(d2, 2 * np.ones(4, np.float32))
    g2 = f.commit()
    assert g0 < g1 < g2
    f.close()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/s/one"), np.ones(4, np.float32))
        np.testing.assert_array_equal(f.read("/s/two"), 2 * np.ones(4, np.float32))


def test_crash_before_commit_preserves_previous(path):
    """Torn write: slabs written but no commit → reopen sees the previous
    generation only (the shadow-page crash-consistency claim)."""
    f = TH5File.create(path)
    d1 = f.create_dataset("/s/one", (4,), "<f4")
    f.write_full(d1, np.ones(4, np.float32))
    f.commit()
    # second session writes data but "crashes" before commit
    d2 = f.create_dataset("/s/two", (4,), "<f4")
    f.write_full(d2, 2 * np.ones(4, np.float32))
    os.close(f.fd)  # simulate process death — no commit, no close()
    f._closed = True
    with TH5File.open(path) as g:
        assert g.exists("/s/one")
        assert not g.exists("/s/two")
        np.testing.assert_array_equal(g.read("/s/one"), np.ones(4, np.float32))


def test_corrupt_superblock_detected(path):
    with TH5File.create(path) as f:
        f.commit()
    with open(path, "r+b") as fh:
        fh.seek(8)
        fh.write(b"\xff\xff")
    with pytest.raises(CorruptFileError):
        TH5File.open(path)


def test_payload_checksum_detects_bitrot(path):
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (1024,), "<u1")
        f.write_full(d, np.zeros(1024, np.uint8), checksum=True)
        f.commit()
        off = d.offset
    with open(path, "r+b") as fh:
        fh.seek(off + 100)
        fh.write(b"\x01")
    with TH5File.open(path) as f:
        with pytest.raises(CorruptFileError):
            f.read("/x", verify=True)
        f.read("/x", verify=False)  # unverified read still possible


def test_concurrent_lock_free_slab_writes(path):
    """The paper's core safety claim: disjoint extents need no locking.
    32 writer threads, one extent each, full coverage, no corruption."""
    n_ranks, rows_per, cols = 32, 64, 16
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (n_ranks * rows_per, cols), "<i4")

        def writer(rank):
            data = np.full((rows_per, cols), rank, dtype=np.int32)
            f.write_rows(d, rank * rows_per, data)

        threads = [threading.Thread(target=writer, args=(r,)) for r in range(n_ranks)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        f.commit()
    with TH5File.open(path) as f:
        got = f.read("/x")
        for r in range(n_ranks):
            assert (got[r * rows_per : (r + 1) * rows_per] == r).all()


def test_alignment_of_extents(path):
    with TH5File.create(path, block_size=4096) as f:
        d1 = f.create_dataset("/a", (3,), "<u1")
        d2 = f.create_dataset("/b", (3,), "<u1")
        assert d1.offset % 4096 == 0
        assert d2.offset % 4096 == 0
        assert d1.offset >= SUPERBLOCK_SIZE


def test_write_bounds_checked(path):
    with TH5File.create(path) as f:
        d = f.create_dataset("/x", (4,), "<f4")
        with pytest.raises(TH5Error):
            f.write_slab(d, 8, np.zeros(4, np.float32))  # 8+16 > 16


def test_children_listing(path):
    with TH5File.create(path) as f:
        f.create_group("/simulation/step_00000001")
        f.create_group("/simulation/step_00000002")
        f.create_dataset("/simulation/step_00000001/x", (1,), "<f4")
        assert f.children("/simulation") == [
            "/simulation/step_00000001",
            "/simulation/step_00000002",
        ]
        assert "/simulation/step_00000001/x" in f.children("/simulation/step_00000001")


def test_readonly_mode(path):
    with TH5File.create(path) as f:
        f.commit()
    with TH5File.open(path, "r") as f:
        with pytest.raises(TH5Error):
            f.create_group("/g")


# -- chunk-record JSON codec & format-version tolerance ------------------------


def test_chunk_record_json_roundtrip_without_stats():
    """The legacy 6-tuple form stays byte-identical: a record with no stats
    encodes to exactly 6 elements (older readers keep parsing it)."""
    from repro.core.container import ChunkRecord

    rec = ChunkRecord(4096, 512, 2048, 0xDEAD, 0xBEEF, 2)
    doc = rec.to_json()
    assert len(doc) == 6 and all(isinstance(x, int) for x in doc)
    back = ChunkRecord.from_json(doc)
    assert (back.offset, back.nbytes, back.raw_nbytes, back.raw_crc32,
            back.stored_crc32, back.codec_id) == (4096, 512, 2048, 0xDEAD, 0xBEEF, 2)
    assert back.stats is None


def test_chunk_record_json_roundtrip_with_stats():
    """The stats-bearing 7-element form round-trips, and a real record's
    stats stay valid for its own chunk after the trip."""
    import numpy as np

    from repro.core.container import ChunkRecord
    from repro.core.query import compute_chunk_stats

    chunk = np.arange(64, dtype="<f4").reshape(16, 4)
    stats = compute_chunk_stats(chunk, raw_crc32=0x1234)
    rec = ChunkRecord(0, 10, 256, 0x1234, 0x5678, 1, stats=stats)
    doc = rec.to_json()
    assert len(doc) == 7
    back = ChunkRecord.from_json(doc)
    assert back.stats is not None
    assert back.stats.valid_for(16, 4, 0x1234)
    assert back.stats.mins == stats.mins and back.stats.maxs == stats.maxs
    assert back.stats.nan_counts == stats.nan_counts
    assert back.stats.finite_counts == stats.finite_counts


def test_chunk_record_decode_is_version_tolerant():
    """Future index writers may append trailing elements or write odd stats
    blobs: decode must take the 6 known fields, treat a null stats slot as
    absent, and turn unparseable stats into a distrusted record instead of
    failing the open."""
    from repro.core.container import ChunkRecord

    base = [0, 10, 256, 1, 2, 0]
    assert ChunkRecord.from_json(base + [None]).stats is None
    extra = ChunkRecord.from_json(base + [None, "future-field", 42])
    assert extra.offset == 0 and extra.stats is None
    garbled = ChunkRecord.from_json(base + [{"not": "a stats record"}])
    assert garbled.stats is not None  # parsed leniently...
    assert not garbled.stats.valid_for(16, 4, 1)  # ...but never trusted


def test_index_without_stats_still_opens_and_reads(path):
    """A committed file whose chunk records carry no stats (an older
    writer) reopens cleanly and reads bit-identically."""
    import numpy as np

    from repro.core.aggregation import ChunkPipeline

    data = np.arange(256, dtype="<f4").reshape(64, 4)
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 16, "zlib")
        ChunkPipeline(f).write(meta, data)
        f.commit()
    with TH5File.open(path, "r+") as f:
        for rec in f.meta("/d").chunks:
            assert rec.stats is not None  # the pipeline recorded stats
            rec.stats = None
        f._dirty = True
        f.commit()
    with TH5File.open(path) as f:
        assert all(r.stats is None for r in f.meta("/d").chunks)
        np.testing.assert_array_equal(f.read("/d"), data)


def test_stats_survive_commit_and_reopen(path):
    """Stats written by the pipeline persist through the CRC'd index and
    still validate against their chunks after reopen."""
    import numpy as np

    from repro.core.aggregation import ChunkPipeline

    rng = np.random.default_rng(11)
    data = rng.normal(size=(96, 6)).astype("<f4")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 32, "zlib")
        ChunkPipeline(f).write(meta, data)
        f.commit()
    with TH5File.open(path) as f:
        for ci, rec in enumerate(f.meta("/d").chunks):
            assert rec.stats is not None
            assert rec.stats.valid_for(32, 6, rec.raw_crc32)
            lo, hi = ci * 32, (ci + 1) * 32
            g0 = rec.stats.group_of(0)
            block = data[lo:hi].reshape(32, 6)
            assert rec.stats.mins[g0] <= block[:, 0].min()
            assert rec.stats.maxs[g0] >= block[:, 0].max()
