"""Trainer integration: learning, crash-resume exactness, TRS branching,
gradient compression, straggler watchdog."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.checkpoint import CheckpointManager
from repro.distributed.compression import ErrorFeedback, int8_roundtrip
from repro.train.data import DataConfig, TokenStream
from repro.train.steps import TrainSetup
from repro.train.trainer import Trainer, TrainerConfig


def tiny_cfg():
    return get_smoke("qwen3-8b").scaled(logit_chunk=64)


def make_trainer(tmp_path, name="run.th5", **kw):
    mgr = CheckpointManager(str(tmp_path / name), common={"arch": "qwen3-smoke"})
    setup = kw.pop("setup", TrainSetup(adamw=__import__("repro.train.optim", fromlist=["AdamWConfig"]).AdamWConfig(lr=3e-3)))
    return Trainer(
        tiny_cfg(),
        mgr,
        setup=setup,
        data=DataConfig(batch=4, seq_len=64, seed=7),
        tcfg=TrainerConfig(checkpoint_every=5, **kw),
    )


def test_loss_decreases(tmp_path):
    t = make_trainer(tmp_path)
    t.init_or_resume()
    metrics = t.run(30)
    first = np.mean([m["loss"] for m in metrics[:5]])
    last = np.mean([m["loss"] for m in metrics[-5:]])
    assert last < first - 0.1, (first, last)
    t.manager.close()


def test_crash_resume_exact(tmp_path):
    """Train 10; 'crash'; resume → identical weights to an uninterrupted run."""
    t1 = make_trainer(tmp_path, "a.th5")
    t1.init_or_resume(seed=3)
    t1.run(10)  # checkpoints at 5 and 10
    w10 = jax.tree.leaves(t1.state["params"])[0].copy()
    t1.run(5)
    w15_direct = np.asarray(jax.tree.leaves(t1.state["params"])[0])
    t1.manager.close()

    # second process: resumes from step 10 snapshot and redoes 5 steps
    t2 = make_trainer(tmp_path, "a.th5")
    start = t2.init_or_resume(seed=999)  # seed ignored on resume
    assert start == 15  # latest snapshot was at 15 (end-of-run save)
    # roll back to the step-10 snapshot explicitly to replay
    _, snap = t2.manager.restore(10)
    t2.state = snap["train_state"]
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(t2.state["params"])[0]), np.asarray(w10))
    t2.run(5)
    w15_replay = np.asarray(jax.tree.leaves(t2.state["params"])[0])
    np.testing.assert_allclose(w15_replay, w15_direct, atol=1e-6)
    t2.manager.close()


def test_torn_checkpoint_resume_falls_back(tmp_path):
    t = make_trainer(tmp_path, "b.th5")
    t.init_or_resume()
    t.run(10)
    t.manager.close()
    # corrupt the newest snapshot's payload
    mgr = CheckpointManager(str(tmp_path / "b.th5"), create=False)
    newest = mgr.steps()[-1]
    meta = mgr.file.meta(f"/simulation/step_{newest:08d}/state/train_state.params.embed")
    with open(str(tmp_path / "b.th5"), "r+b") as fh:
        fh.seek(meta.offset + 5)
        fh.write(b"\xff\xff\xff")
    mgr.close()
    t2 = make_trainer(tmp_path, "b.th5")
    start = t2.init_or_resume()
    assert start == 5  # fell back to the previous valid snapshot
    t2.manager.close()


def test_trs_branch_lr_steering(tmp_path):
    """Roll back and continue with a different LR → branches diverge;
    lineage records the overlay (time-reversible steering for training)."""
    t = make_trainer(tmp_path, "root.th5")
    t.init_or_resume()
    t.run(10)
    base_loss = t.metrics[-1]["loss"]

    import dataclasses
    from repro.train.optim import AdamWConfig

    br = t.branch_from(
        5,
        str(tmp_path / "lowlr.th5"),
        overlay={"lr": 1e-5},
        adamw=AdamWConfig(lr=1e-5),
    )
    assert int(br.state["step"]) == 5
    br.run(5)
    # same step count, different trajectory
    p_main = np.asarray(jax.tree.leaves(t.state["params"])[0])
    p_branch = np.asarray(jax.tree.leaves(br.state["params"])[0])
    assert np.abs(p_main - p_branch).max() > 1e-6

    from repro.core.steering import BranchManager

    bm = BranchManager(br.manager)
    assert bm.effective_config()["lr"] == 1e-5
    assert 5 in bm.available_steps()
    t.manager.close()
    br.manager.close()


def test_data_stream_deterministic():
    cfg = tiny_cfg()
    s1 = TokenStream(cfg, DataConfig(batch=2, seq_len=32, seed=5))
    s2 = TokenStream(cfg, DataConfig(batch=2, seq_len=32, seed=5))
    b1, b2 = s1.batch(17), s2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = s1.batch(18)
    assert np.abs(np.asarray(b1["tokens"]) - np.asarray(b3["tokens"])).max() > 0
    # labels are next-token shifted
    full1 = s1.batch(17)
    np.testing.assert_array_equal(
        np.asarray(full1["tokens"][:, 1:]), np.asarray(full1["labels"][:, :-1])
    )


def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((257, 33)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal(100) * 1e-3, jnp.float32)}
    out = int8_roundtrip(g)
    for k in g:
        err = np.abs(np.asarray(out[k]) - np.asarray(g[k]))
        scale = np.abs(np.asarray(g[k])).max()
        assert err.max() <= scale / 127.0 * 1.01


def test_error_feedback_converges_quadratic():
    """EF-compressed GD still converges on a quadratic bowl."""
    ef = ErrorFeedback()
    w = {"w": jnp.ones(512) * 5.0}
    target = jnp.zeros(512)
    residual = ef.init(w)
    for _ in range(200):
        grad = {"w": (w["w"] - target)}
        cgrad, residual = ef.compress(grad, residual)
        w = {"w": w["w"] - 0.1 * cgrad["w"]}
    assert float(jnp.abs(w["w"]).max()) < 1e-2


def test_straggler_watchdog(tmp_path):
    t = make_trainer(tmp_path, "c.th5")
    t.init_or_resume()
    # synthetic timings: steady 10ms with one 100ms spike
    for dt in [0.01] * 10 + [0.1] + [0.01] * 5:
        t._watchdog(dt, 0)
    assert t.straggler.flagged == 1
    assert t.straggler.slowest_s == pytest.approx(0.1)
    t.manager.close()
