"""Serving engine + dry-run integration on a small forced-device mesh."""

import numpy as np
import pytest

from repro.configs import get_smoke
from tests._subproc import run_with_devices


def test_batched_server_generates():
    import jax

    from repro.models import transformer
    from repro.serve.engine import BatchedServer, Request

    cfg = get_smoke("qwen3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32), max_new=4)
        for i in range(4)
    ]
    server = BatchedServer(cfg, params, max_batch=2, max_len=32)
    stats = server.serve(reqs)
    assert stats.n_generated == 16
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)


def test_greedy_decode_consistency_with_cacheless():
    """Greedy continuation via the server == argmax over full forwards."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer
    from repro.serve.engine import BatchedServer, Request

    cfg = get_smoke("yi-9b").scaled(param_dtype="float32", compute_dtype="float32")
    params = transformer.init_model(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)

    req = Request(rid=0, prompt=prompt, max_new=3)
    BatchedServer(cfg, params, max_batch=1, max_len=20).serve([req])

    toks = list(prompt)
    for _ in range(3):
        x, _, _ = transformer.hidden_states(params, cfg, jnp.asarray([toks], jnp.int32))
        lg = transformer.logits(params, cfg, x[:, -1:])
        toks.append(int(jnp.argmax(lg[0, 0])))
    assert req.out_tokens == toks[len(prompt):]


DRYRUN_CODE = r"""
import jax
from repro.configs import get_smoke
from repro.configs.shapes import ShapeSpec
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_test_mesh
from repro.train.steps import TrainSetup
from repro.analysis import roofline as rf

# small production-shaped mesh: (pod, data, model)
mesh = make_test_mesh(2, 2, pod=2)
cfg = get_smoke("qwen3-8b")
shape = ShapeSpec("tiny_train", 64, 8, "train")
jitted, args = build_cell(cfg, shape, mesh, TrainSetup(), {})
with mesh:
    compiled = jitted.lower(*args).compile()
stats = rf.parse_collectives(compiled.as_text(), 8)
assert stats.total_wire_bytes > 0, "expected collectives on a sharded train step"
ma = compiled.memory_analysis()
assert ma.temp_size_in_bytes > 0
print("DRYRUN-OK", stats.op_counts)
"""


def test_dryrun_pipeline_small_mesh():
    """End-to-end build_cell→lower→compile→roofline parse on 8 devices,
    multi-pod mesh topology — the dry-run machinery itself under test."""
    out = run_with_devices(DRYRUN_CODE, 8, timeout=900)
    assert "DRYRUN-OK" in out
