"""Multi-client behaviour of the TH5 data service (``repro.service``).

The broker adds admission control, fair scheduling, shared-cache reuse and
serialized steering ON TOP of the single-caller read paths — so the
contract under test is: payloads stay bit-identical to direct ``TH5File``
calls under concurrency, a full queue rejects instead of piling up,
steering never races the lineage, and a second client replaying a window
another client already warmed decodes NOTHING new.
"""

import os
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.aggregation import AggregationConfig, ChunkPipeline
from repro.core.checkpoint import CheckpointManager, CodecPolicy
from repro.core.container import READ_COUNTER, TH5File
from repro.service import (
    AdmissionError,
    CatalogQuery,
    DataService,
    HyperslabQuery,
    PingQuery,
    QosClass,
    ServiceConfig,
    StatsQuery,
    SteeringRequest,
    WindowQuery,
)

ROWS, COLS, CHUNK_ROWS = 1024, 64, 128
DS_U = "/simulation/step_00000000/state/fields/u"
DS_FLAT = "/simulation/step_00000000/state/flat"


@pytest.fixture()
def run_file(tmp_path):
    """One run file with a compressed chunked leaf and a contiguous leaf."""
    rng = np.random.default_rng(42)
    u = (rng.integers(0, 1024, (ROWS, COLS)) / 1024.0).astype(np.float32)
    flat = rng.random((ROWS, COLS)).astype(np.float32)
    path = str(tmp_path / "run.th5")
    with TH5File.create(path) as f:
        mu = f.create_chunked_dataset(DS_U, u.shape, "<f4", CHUNK_ROWS, "shuffle+zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            pipe.write(mu, u)
        mf = f.create_dataset(DS_FLAT, flat.shape, "<f4")
        f.write_full(mf, flat, checksum=True)
        f.commit()
    return path, u, flat


# -- bit-identical results under concurrency -----------------------------------


def test_concurrent_hyperslab_and_lod_bit_identical(run_file):
    """8 clients × mixed hyperslab / window traffic over one file: every
    response equals the direct single-caller read of the same selection."""
    path, u, flat = run_file
    rng = np.random.default_rng(7)
    scripts = []
    for c in range(8):
        script = []
        for _ in range(12):
            if rng.integers(2):
                lo = int(rng.integers(0, ROWS - 64))
                n = int(rng.integers(1, 256))
                n = min(n, ROWS - lo)
                c0 = int(rng.integers(0, COLS - 8))
                ds = DS_U if rng.integers(2) else DS_FLAT
                script.append((HyperslabQuery(ds, lo, n, cols=(c0, c0 + 8)), None))
            else:
                rows = tuple(int(r) for r in np.sort(rng.choice(ROWS, size=96, replace=False)))
                script.append((WindowQuery(DS_U, rows), None))
        scripts.append(script)

    def expected(req):
        src = u if req.dataset == DS_U else flat
        if isinstance(req, HyperslabQuery):
            out = src[req.row_start : req.row_start + req.n_rows]
            return out[:, req.cols[0] : req.cols[1]] if req.cols else out
        return src[list(req.rows)]

    with DataService(path, ServiceConfig(n_workers=4, max_queue=256)) as svc:
        def run_client(cid):
            futs = [(svc.submit(f"c{cid}", req), req) for req, _ in scripts[cid]]
            for fut, req in futs:
                np.testing.assert_array_equal(fut.result().value, expected(req))

        with ThreadPoolExecutor(max_workers=8) as pool:
            for f_ in [pool.submit(run_client, c) for c in range(8)]:
                f_.result()
        st = svc.stats()
        assert st.completed == 8 * 12
        assert st.failed == 0 and st.rejected == 0
        assert sorted(st.clients) == [f"c{c}" for c in range(8)]
        # fair-queue bookkeeping drained fully
        assert st.queue_depth == 0 and st.inflight == 0


def test_window_sessions_concurrent_match_direct_reads(run_file):
    """Concurrent per-client LOD sessions (double-buffered through the
    service queue) return exactly what direct read_row_indices returns."""
    path, u, _ = run_file
    windows = [(lo, lo + 256) for lo in range(0, ROWS - 256 + 1, 128)]
    with TH5File.open(path) as direct:
        want = [
            direct.read_row_indices(DS_U, list(range(lo, hi, 4)))
            for lo, hi in windows
        ]
    with DataService(path, ServiceConfig(n_workers=4, max_queue=128)) as svc:
        def play(cid):
            ses = svc.open_window_session(cid, DS_U, windows, max_rows=64)
            got = list(ses)
            assert len(got) == len(want)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(g, w)
            return ses.windows_served

        with ThreadPoolExecutor(max_workers=6) as pool:
            served = [f.result() for f in [pool.submit(play, f"v{i}") for i in range(6)]]
        assert served == [len(windows)] * 6


def test_session_explicit_row_windows_not_misrouted(run_file):
    """Explicit (non-contiguous / duplicate-bearing) row selections whose
    endpoints happen to look contiguous must NOT be rewritten into
    hyperslabs — the session returns exactly the requested rows."""
    path, u, _ = run_file
    tricky = [(2, 7, 4), (2, 2, 4), (5, 6, 7), [40, 39, 42]]
    with DataService(path) as svc:
        ses = svc.open_window_session("t", DS_U, tricky)
        for rows, got in zip(tricky, ses):
            np.testing.assert_array_equal(got, u[list(rows)])


def test_verified_hyperslab_and_column_slice(run_file):
    """verify=True routes through the CRC-checking paths — chunked partial,
    contiguous full AND contiguous partial (whole-payload CRC re-read,
    never a silent downgrade) — and stays bit-identical."""
    path, u, flat = run_file
    with DataService(path) as svc:
        r = svc.request("v", HyperslabQuery(DS_U, 64, 512, verify=True))
        np.testing.assert_array_equal(r.value, u[64:576])
        r2 = svc.request("v", HyperslabQuery(DS_FLAT, 0, ROWS, verify=True))
        np.testing.assert_array_equal(r2.value, flat)
        r3 = svc.request("v", HyperslabQuery(DS_U, 0, ROWS, cols=(3, 9), verify=True))
        np.testing.assert_array_equal(r3.value, u[:, 3:9])
        r4 = svc.request("v", HyperslabQuery(DS_FLAT, 100, 50, verify=True))
        np.testing.assert_array_equal(r4.value, flat[100:150])


def test_partial_contiguous_verify_detects_corruption(run_file):
    """A partial verified hyperslab of a contiguous dataset must check the
    whole-payload CRC: corruption OUTSIDE the requested rows still raises
    (the client asked for integrity, not a silent downgrade)."""
    from repro.core.container import CorruptFileError

    path, u, flat = run_file
    meta_off = TH5File.open(path)
    off = meta_off.meta(DS_FLAT).offset
    meta_off.close()
    with open(path, "r+b") as fh:  # flip bytes in the LAST row's extent
        fh.seek(off + (ROWS - 1) * COLS * 4)
        fh.write(b"\xff" * 8)
    with DataService(path) as svc:
        fut = svc.submit("v", HyperslabQuery(DS_FLAT, 0, 10, verify=True))
        with pytest.raises(CorruptFileError, match="payload CRC mismatch"):
            fut.result()
        # unverified read of the untouched rows still serves bytes
        got = svc.request("v", HyperslabQuery(DS_FLAT, 0, 10)).value
        np.testing.assert_array_equal(got, flat[:10])
        st = svc.stats()
        assert st.failed == 1 and st.completed == 1


# -- admission control ---------------------------------------------------------


def test_admission_rejects_when_queue_full(run_file):
    """Bounded queue: with the single worker gated, the (max_queue+1)-th
    submit is REJECTED with AdmissionError (and accounted), nothing hangs,
    and service resumes normally once the gate opens."""
    path, u, _ = run_file
    gate = threading.Event()
    with DataService(path, ServiceConfig(n_workers=1, max_queue=2)) as svc:
        try:
            blocker = svc.submit("greedy", PingQuery(gate=gate))
            # worker is (or will be) busy on the gated ping; fill the queue
            queued = []
            while len(queued) < 2:
                try:
                    queued.append(svc.submit("greedy", PingQuery()))
                except AdmissionError:
                    pass  # racing the worker pickup; retry
            with pytest.raises(AdmissionError) as ei:
                for _ in range(3):  # queue holds 2: the 3rd must reject
                    queued.append(svc.submit("greedy", PingQuery()))
            assert ei.value.queue_depth == 2
            assert ei.value.client == "greedy"  # the BUSY reply's "why"
            st = svc.stats()
            assert st.rejected >= 1
            assert st.clients["greedy"].rejected >= 1
        finally:
            gate.set()  # never leave the worker gated (close() would hang)
        for fut in [blocker] + queued:
            fut.result(timeout=30)
        # recovered: new requests are admitted and served
        got = svc.request("greedy", HyperslabQuery(DS_U, 0, 8)).value
        np.testing.assert_array_equal(got, u[:8])


def test_fair_scheduling_round_robin(run_file):
    """A client with a deep backlog cannot starve another client: with one
    worker, B's single request (submitted after A's backlog) is served
    after at most one more of A's — round-robin, not FIFO-by-client."""
    path, _, _ = run_file
    gate = threading.Event()
    order = []
    with DataService(path, ServiceConfig(n_workers=1, max_queue=64)) as svc:
        try:
            blocker = svc.submit("a", PingQuery(gate=gate))
            backlog = [svc.submit("a", PingQuery()) for _ in range(8)]
            b = svc.submit("b", PingQuery())
            for fut, tag in [(f, "a") for f in backlog] + [(b, "b")]:
                fut.add_done_callback(lambda _f, t=tag: order.append(t))
        finally:
            gate.set()
        blocker.result(timeout=30)
        for f in backlog + [b]:
            f.result(timeout=30)
    # b entered the rotation with a's backlog already queued: it must be
    # served within the first two completions, not after all 8 of a's
    assert "b" in order[:2], order


# -- QoS: weights + token-bucket rate limiting ---------------------------------


def test_bulk_client_cannot_starve_interactive(run_file):
    """The QoS starvation contract: with one gated worker, a bulk client's
    12-deep backlog ahead of an interactive client's 3 requests must not
    delay them — weight 4 vs 1 serves all interactive work within the
    first few completions."""
    path, _, _ = run_file
    gate = threading.Event()
    order = []
    with DataService(path, ServiceConfig(n_workers=1, max_queue=64)) as svc:
        svc.set_client_class("replayer", "bulk")
        svc.set_client_class("viewer", "interactive")
        try:
            blocker = svc.submit("replayer", PingQuery(gate=gate))
            backlog = [svc.submit("replayer", PingQuery()) for _ in range(12)]
            quick = [svc.submit("viewer", PingQuery()) for _ in range(3)]
            for fut, tag in [(f, "bulk") for f in backlog] + [(f, "inter") for f in quick]:
                fut.add_done_callback(lambda _f, t=tag: order.append(t))
        finally:
            gate.set()
        for f in backlog + quick + [blocker]:
            f.result(timeout=30)
        st = svc.stats()
    # all 3 interactive requests inside the first 5 completions: the bulk
    # backlog cannot monopolize the worker (weight 4 vs 1)
    assert order.count("inter") == 3
    assert [t for t in order[:5]].count("inter") == 3, order
    assert st.clients["viewer"].qos_class == "interactive"
    assert st.clients["replayer"].qos_class == "bulk"
    assert st.qos["bulk"]["requests"] == 13


def test_equal_weights_still_round_robin(run_file):
    """Two clients of the SAME class alternate exactly (the PR-4 fairness
    behaviour is the degenerate case of weighted virtual time)."""
    path, _, _ = run_file
    gate = threading.Event()
    order = []
    with DataService(path, ServiceConfig(n_workers=1, max_queue=64)) as svc:
        try:
            blocker = svc.submit("a", PingQuery(gate=gate))
            futs = [svc.submit("a", PingQuery()) for _ in range(4)]
            futs += [svc.submit("b", PingQuery()) for _ in range(4)]
            for i, f in enumerate(futs):
                f.add_done_callback(lambda _f, t="ab"[i // 4]: order.append(t))
        finally:
            gate.set()
        for f in futs + [blocker]:
            f.result(timeout=30)
    assert order == ["b", "a"] * 4 or order == ["a", "b"] * 4, order


def test_token_bucket_rate_limits_bulk_but_drains_on_close(run_file):
    """A rate-limited bulk client: its first (large) read empties the
    bucket, so its queued follow-up is DEFERRED — interactive traffic
    submitted later still flows — and close() drains it regardless."""
    path, u, _ = run_file
    cfg = ServiceConfig(
        n_workers=2,
        qos_classes=(
            QosClass("interactive", weight=4),
            # 100 B/s with a 1-byte burst: one response puts the bucket
            # ~128 KB in debt — it cannot refill within this test's lifetime
            QosClass("bulk", weight=1, rate_bytes_per_s=100.0, burst_bytes=1),
        ),
    )
    with DataService(path, cfg) as svc:
        svc.set_client_class("replayer", "bulk")
        first = svc.request("replayer", HyperslabQuery(DS_U, 0, 512))
        np.testing.assert_array_equal(first.value, u[:512])
        deferred = svc.submit("replayer", PingQuery())
        for _ in range(5):  # later interactive traffic overtakes the debtor
            assert svc.request("viewer", PingQuery()).value is None
        assert not deferred.done(), "rate-limited request ran with an empty bucket"
        # re-declaring the SAME class (what the transport does on every new
        # connection) must NOT refill the bucket — debt survives reconnects
        svc.set_client_class("replayer", "bulk")
        assert svc.request("viewer", PingQuery()).value is None
        assert not deferred.done(), "reconnect laundered the token-bucket debt"
        # ...and debt survives class HOPPING too: bulk → interactive (the
        # unlimited class serves the deferred ping) → bulk again must carry
        # the negative balance, not start from a fresh burst
        svc.set_client_class("replayer", "interactive")
        assert deferred.result(timeout=30).value is None  # now eligible
        svc.set_client_class("replayer", "bulk")
        deferred = svc.submit("replayer", PingQuery())
        assert svc.request("viewer", PingQuery()).value is None
        assert not deferred.done(), "class hopping laundered the token-bucket debt"
        st = svc.stats()
        assert st.clients["replayer"].throttled > 0
        assert st.qos["bulk"]["throttled"] > 0
        assert st.qos["bulk"]["rate_bytes_per_s"] == 100.0
    # close() drained the deferred request (admitted work always completes)
    assert deferred.result(timeout=5).value is None


def test_qos_config_validation():
    with pytest.raises(ValueError, match="weight"):
        QosClass("x", weight=0)
    with pytest.raises(ValueError, match="rate_bytes_per_s"):
        QosClass("x", rate_bytes_per_s=-1.0)
    with pytest.raises(ValueError, match="default_class"):
        ServiceConfig(qos_classes=(QosClass("a"),), default_class="b")
    with pytest.raises(ValueError, match="duplicate"):
        ServiceConfig(qos_classes=(QosClass("a"), QosClass("a")), default_class="a")


def test_stats_query_inline_even_when_queue_full(run_file):
    """StatsQuery short-circuits the admission queue: it answers while the
    service is saturated and leaves no trace in the accounting."""
    path, _, _ = run_file
    gate = threading.Event()
    with DataService(path, ServiceConfig(n_workers=1, max_queue=1)) as svc:
        try:
            blocker = svc.submit("g", PingQuery(gate=gate))
            while True:
                try:
                    queued = svc.submit("g", PingQuery())
                    break
                except AdmissionError:
                    pass
            with pytest.raises(AdmissionError):
                for _ in range(3):
                    svc.submit("g", PingQuery())
            st = svc.request("observer", StatsQuery()).value  # queue is FULL
            assert st.queue_depth >= 1 and st.rejected >= 1
            assert "observer" not in st.clients  # not accounted
            assert "StatsQuery" not in st.requests_by_type
        finally:
            gate.set()
        blocker.result(timeout=30)
        queued.result(timeout=30)
    # a CLOSED service refuses StatsQuery like any other request — a
    # monitoring loop must learn the service is gone, not read stale state
    from repro.core.container import TH5Error

    with pytest.raises(TH5Error, match="closed"):
        svc.submit("observer", StatsQuery())


# -- cross-client cache sharing ------------------------------------------------


def test_second_client_window_replay_decodes_nothing(run_file):
    """The cache-sharing contract: after client A cold-replays a window
    set, client B replaying the same windows decodes ZERO new chunks (all
    shared-cache hits) — N viewers of one run cost ~1 decode."""
    path, u, _ = run_file
    windows = [(lo, lo + 256) for lo in range(0, ROWS - 256 + 1, 128)]
    with DataService(path, ServiceConfig(n_workers=4, max_queue=128)) as svc:
        for _ in svc.open_window_session("A", DS_U, windows):
            pass
        decoded_after_a = svc.file.read_stats.n_chunks if svc.file.read_stats else 0
        assert decoded_after_a > 0  # A's replay was genuinely cold
        for _ in svc.open_window_session("B", DS_U, windows):
            pass
        decoded_after_b = svc.file.read_stats.n_chunks
        assert decoded_after_b == decoded_after_a  # B decoded nothing new
        st = svc.stats()
        assert st.clients["B"].chunk_misses == 0
        assert st.clients["B"].chunk_hits > 0
        assert st.clients["B"].cache_hit_rate == 1.0


def test_shared_file_registry_across_service_instances(run_file):
    """Two DataService instances over one path share ONE TH5File (one
    cache, one decode pool) — and the file closes only with the last."""
    path, u, _ = run_file
    svc1 = DataService(path)
    svc2 = DataService(path)
    try:
        assert svc1.file is svc2.file
        svc1.request("x", HyperslabQuery(DS_U, 0, 256))
        # the decode work is visible through the OTHER service's handle
        assert svc2.file.read_stats is not None
    finally:
        svc1.close()
        # still usable through svc2 after svc1 released its ref
        got = svc2.request("y", HyperslabQuery(DS_U, 0, 16)).value
        np.testing.assert_array_equal(got, u[:16])
        svc2.close()


# -- catalog -------------------------------------------------------------------


def test_catalog_lists_without_decoding(run_file):
    """CatalogQuery answers steps/leaves/codec stats from the index alone:
    zero read syscalls, zero decodes."""
    path, u, flat = run_file
    with DataService(path) as svc:
        READ_COUNTER.reset()
        cat = svc.request("browser", CatalogQuery()).value
        syscalls, nbytes = READ_COUNTER.snapshot()
        assert (syscalls, nbytes) == (0, 0)
        assert svc.file.read_stats is None  # no decode pipeline activity
    assert cat.steps == (0,)
    assert cat.leaves_by_step[0] == ("fields/u", "flat")
    by_path = {d.path: d for d in cat.datasets}
    du = by_path[DS_U]
    assert du.codec == "shuffle+zlib"
    assert du.n_chunks == ROWS // CHUNK_ROWS
    assert du.nbytes == u.nbytes
    assert 0 < du.stored_nbytes < u.nbytes and du.ratio > 1.0
    dflat = by_path[DS_FLAT]
    assert dflat.codec == "none" and dflat.stored_nbytes == flat.nbytes


# -- steering ------------------------------------------------------------------


def test_concurrent_steering_serialized_and_consistent(tmp_path):
    """6 concurrent branch requests + interleaved reads: every branch
    lands with a correct lineage record, the endpoint executes them
    serially (single endpoint per file, op counter == request count), and
    a chained rollback sees the committed lineage."""
    root_path = str(tmp_path / "root.th5")
    with CheckpointManager(root_path, common={"lamp_T": 324.66}) as mgr:
        for s in (10, 20, 30):
            mgr.save(s, {"T": np.full((64, 4), float(s), np.float32)})
    with DataService(root_path, ServiceConfig(n_workers=4, max_queue=64)) as svc:
        def steer(i):
            child = str(tmp_path / f"branch_{i}.th5")
            return svc.request(
                f"s{i}", SteeringRequest.branch(20, child, {"lamp_T": 350.0 + i})
            ).value
        with ThreadPoolExecutor(max_workers=6) as pool:
            results = [f.result() for f in [pool.submit(steer, i) for i in range(6)]]
        for i, res in enumerate(results):
            assert res.op == "branch" and res.branch_step == 20
            assert res.steps == (10, 20)  # parent's future (30) invisible
            assert res.lineage[0][0] == os.path.realpath(root_path) or res.lineage[0][0] == root_path
            assert res.lineage[-1] == (str(tmp_path / f"branch_{i}.th5"), 20)
        assert svc.steering.n_ops == 6
        # chained rollback through one branch sees its committed lineage
        with DataService(str(tmp_path / "branch_0.th5")) as child_svc:
            rb = child_svc.request("s0", SteeringRequest.rollback(10, str(tmp_path / "rb.th5"))).value
            assert rb.steps == (10,)
            assert [p for p, _ in rb.lineage][-1] == str(tmp_path / "rb.th5")
        lin = svc.request("any", SteeringRequest.lineage()).value
        assert lin.steps == (10, 20, 30)


# -- batched adjacent-chunk fetches (satellite) --------------------------------


def test_batched_fetch_identical_and_fewer_syscalls(run_file):
    """DecodePipeline preadv batching: a cold multi-chunk read issues
    strictly fewer read syscalls than the per-chunk baseline and returns
    bit-identical data."""
    path, u, _ = run_file
    counts = {}
    for batch in (True, False):
        with TH5File.open(path) as f:
            f.set_decode_config(AggregationConfig(n_aggregators=4), batch_fetch=batch)
            READ_COUNTER.reset()
            got = f.read(DS_U)
            np.testing.assert_array_equal(got, u)
            counts[batch], _ = READ_COUNTER.snapshot()
            assert f.read_stats.n_chunks == ROWS // CHUNK_ROWS  # fully cold
    assert counts[True] < counts[False]
    # ~one syscall per in-flight window (8 chunks) vs one per chunk
    assert counts[True] <= -(-(ROWS // CHUNK_ROWS) // 8) + 1
    assert counts[False] == ROWS // CHUNK_ROWS


# -- predicate pushdown through the broker -------------------------------------


def test_query_through_broker_matches_direct_and_counts_pruning(run_file):
    """A QueryRequest through DataService returns exactly what a direct
    TH5File.query returns, and ServiceStats exposes the pruning economics
    (chunks_scanned / chunks_pruned / pruned_ratio)."""
    from repro.core.query import col
    from repro.service import QueryRequest

    path, u, flat = run_file
    pred = (abs(col(0)) > 0.45) & (col(3) <= 0.9)
    with TH5File.open(path) as f:
        want = f.query(DS_U, pred, row_start=100, n_rows=800)
    with DataService(path, ServiceConfig(n_workers=2)) as svc:
        got = svc.submit("q1", QueryRequest(DS_U, pred, row_start=100, n_rows=800)).result().value
        assert got.rows.tobytes() == want.rows.tobytes()
        np.testing.assert_array_equal(got.mask, want.mask)
        np.testing.assert_array_equal(got.index, want.index)
        assert (got.n_chunks, got.chunks_pruned, got.chunks_decoded) == (
            want.n_chunks, want.chunks_pruned, want.chunks_decoded)
        # a hopeless predicate: every chunk pruned, visible in the stats
        res = svc.submit("q1", QueryRequest(DS_U, col(0) > 1e9)).result().value
        assert res.chunks_pruned == res.n_chunks == ROWS // CHUNK_ROWS
        stats = svc.stats()
        assert stats.chunks_scanned == want.n_chunks + res.n_chunks
        assert stats.chunks_pruned == want.chunks_pruned + res.n_chunks
        assert stats.pruned_ratio == stats.chunks_pruned / stats.chunks_scanned


def test_remote_query_bit_identical_to_in_process(run_file):
    """The same QueryRequest through the socket transport: rows, mask,
    index and every counter identical to the in-process broker answer,
    and the new ServiceStats fields survive the wire."""
    import tempfile

    from repro.core.query import col
    from repro.service import QueryRequest, RemoteDataService, ServiceServer

    path, u, flat = run_file
    pred = (col(2) > 0.8) | ~(abs(col(5)) <= 0.99)
    req = QueryRequest(DS_U, pred, row_start=64, n_rows=900)
    with DataService(path, ServiceConfig(n_workers=2)) as svc:
        want = svc.submit("loc", req).result().value
        with tempfile.TemporaryDirectory(prefix="th5q", dir="/tmp") as d:
            with ServiceServer(svc, os.path.join(d, "q.sock")) as server:
                with RemoteDataService(server.address) as remote:
                    got = remote.request("rem", req).value
                    rstats = remote.request("rem", StatsQuery()).value
    assert got.rows.tobytes() == want.rows.tobytes()
    assert got.rows.dtype == want.rows.dtype and got.rows.shape == want.rows.shape
    np.testing.assert_array_equal(got.mask, want.mask)
    np.testing.assert_array_equal(got.index, want.index)
    assert (got.row_start, got.n_chunks, got.chunks_pruned, got.chunks_decoded,
            got.invalid_stats) == (want.row_start, want.n_chunks,
                                   want.chunks_pruned, want.chunks_decoded,
                                   want.invalid_stats)
    assert rstats.chunks_scanned == 2 * want.n_chunks
    assert rstats.chunks_pruned == 2 * want.chunks_pruned
