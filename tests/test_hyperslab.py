"""Property tests for the hyperslab planner — the lock-free invariants."""

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import hyperslab
from repro.core.hyperslab import (
    Extent,
    align_up,
    exclusive_prefix_sum,
    plan_bytes,
    plan_rows,
    validate_plan,
)

counts_strategy = st.lists(st.integers(min_value=0, max_value=10_000), min_size=1, max_size=256)


@given(counts=counts_strategy, row_bytes=st.integers(min_value=1, max_value=65536))
@settings(max_examples=200, deadline=None)
def test_plan_rows_invariants(counts, row_bytes):
    plan = plan_rows(counts, row_bytes)
    validate_plan(plan)  # exact cover + disjointness + ordering
    # rank ordering and paper's row-index semantics
    assert plan.total_rows == sum(counts)
    for r, c in enumerate(counts):
        lo, hi = plan.row_range(r)
        assert hi - lo == c
        ext = plan.extent_for(r)
        assert ext.offset == lo * row_bytes
        assert ext.nbytes == c * row_bytes
    # root grid (first grid of rank 0) is always row 0
    assert plan.row_range(0)[0] == 0


@given(counts=counts_strategy)
@settings(max_examples=200, deadline=None)
def test_exscan_matches_numpy(counts):
    got = exclusive_prefix_sum(np.array(counts))
    want = np.concatenate([[0], np.cumsum(counts)[:-1]]) if len(counts) > 1 else np.array([0])
    np.testing.assert_array_equal(got, want)


@given(nbytes=st.lists(st.integers(min_value=0, max_value=1 << 20), min_size=1, max_size=128))
@settings(max_examples=100, deadline=None)
def test_plan_bytes_invariants(nbytes):
    plan = plan_bytes(nbytes)
    validate_plan(plan)
    assert plan.total_bytes == sum(nbytes)


@given(
    offset=st.integers(min_value=0, max_value=1 << 40),
    alignment=st.sampled_from([1, 2, 512, 4096, 65536, 1 << 20, 3]),
)
def test_align_up(offset, alignment):
    a = align_up(offset, alignment)
    assert a >= offset
    assert a % alignment == 0 if alignment > 1 else a == offset
    assert a - offset < max(alignment, 1)


def test_extent_end():
    assert Extent(0, 100, 28).end == 128


def test_plan_rejects_bad_input():
    with pytest.raises(ValueError):
        plan_rows([-1], 8)
    with pytest.raises(ValueError):
        plan_rows([1], 0)
    with pytest.raises(ValueError):
        plan_rows(np.zeros((2, 2)), 8)


def test_validate_catches_overlap():
    plan = plan_rows([2, 3], 16)
    bad = hyperslab.SlabPlan(
        total_rows=plan.total_rows,
        row_bytes=plan.row_bytes,
        row_starts=plan.row_starts,
        row_counts=plan.row_counts,
        extents=(Extent(0, 0, 48), Extent(1, 32, 48)),
    )
    with pytest.raises(AssertionError):
        validate_plan(bad)
