"""Sharded SN/DN service (``shard.py`` / ``frontnode.py`` / ``datanode.py``).

Three layers of contract:

* **ownership** — the consistent hash is deterministic across processes
  and ``PYTHONHASHSEED``, balanced within small factors, and *stable*
  under cluster growth: every chunk that changes owner when a node is
  added moves TO the new node (nothing reshuffles between old nodes);
* **planning/stitching** — per-owner runs and row partitions cover the
  request exactly and reassemble bit-identically (pure, no processes);
* **end-to-end** — a front node over real data-node subprocesses answers
  hyperslab / window / query / subscribe traffic bit-identically to a
  single-process broker, rolls up per-node stats, and turns a data node
  dying mid-request into a typed ``RetryableError`` (chaos marker).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import codecs as _codecs
from repro.core.container import TH5File
from repro.core.query import col
from repro.service import (
    DataService,
    HyperslabQuery,
    QueryRequest,
    RemoteDataService,
    RetryableError,
    ServiceConfig,
    ServiceFrontNode,
    ServiceServer,
    ServiceStats,
    StatsQuery,
    SubscribeRequest,
    WindowQuery,
    chunk_owner,
    ownership_histogram,
)
from repro.service.shard import (
    partition_rows,
    plan_runs,
    stitch_hyperslab,
    stitch_window,
)
from repro.service.stats import merge_service_stats

ROWS, COLS, CHUNK_ROWS = 640, 16, 32
N_CHUNKS = ROWS // CHUNK_ROWS
DS = "/simulation/step_00000000/state/fields/u"
_CODEC = _codecs.get_codec("zlib")


def _data(rows=ROWS, seed=7):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((rows, COLS)).astype("<f4")


def _build_run(path, data):
    f = TH5File.create(path)
    meta = f.create_chunked_dataset(DS, (len(data), COLS), "<f4", CHUNK_ROWS)
    f.commit()
    for ci in range(len(data) // CHUNK_ROWS):
        arr = data[ci * CHUNK_ROWS : (ci + 1) * CHUNK_ROWS]
        payload, raw_n, raw_crc, stored_crc, cid = _codecs.encode_chunk(_CODEC, arr)
        f.append_chunk(
            meta, payload, raw_nbytes=raw_n, raw_crc32=raw_crc,
            stored_crc32=stored_crc, codec_id=cid,
        )
    f.commit()
    f.close()


# -- consistent-hash ownership -------------------------------------------------


def test_ownership_deterministic_across_processes():
    """The ring must not depend on this interpreter's hash salt: a child
    process with a DIFFERENT PYTHONHASHSEED computes the same owners."""
    sample = [(DS, ci) for ci in range(32)] + [("/other/ds", ci) for ci in range(8)]
    here = [chunk_owner(d, ci, 4) for d, ci in sample]
    prog = (
        "from repro.service.shard import chunk_owner;"
        f"print([chunk_owner(d, ci, 4) for d, ci in {sample!r}])"
    )
    env = dict(os.environ, PYTHONHASHSEED="12345")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", prog], env=env, capture_output=True, text=True
    )
    assert out.returncode == 0, out.stderr
    assert eval(out.stdout.strip()) == here


def test_ownership_stable_under_growth():
    """Adding node N to an N-node ring only moves chunks TO the new node —
    the consistent-hashing property that makes rescaling cheap."""
    n_chunks = 600
    for n in (1, 2, 3, 4, 7):
        before = [chunk_owner(DS, ci, n) for ci in range(n_chunks)]
        after = [chunk_owner(DS, ci, n + 1) for ci in range(n_chunks)]
        moved = [(b, a) for b, a in zip(before, after) if b != a]
        assert moved, f"growing {n}->{n+1} moved nothing (ring degenerate?)"
        assert all(a == n for _, a in moved), (
            f"growth {n}->{n+1} reshuffled between OLD nodes: "
            f"{[(b, a) for b, a in moved if a != n][:5]}"
        )
        # and the new node claims roughly its fair share, never the world
        share = len(moved) / n_chunks
        assert 0.0 < share < 3.0 / (n + 1)


def test_ownership_balanced():
    hist = ownership_histogram(DS, 1000, 4)
    assert sum(hist) == 1000
    fair = 1000 / 4
    for count in hist:
        assert 0.3 * fair < count < 2.5 * fair, hist


# -- planning + stitching (pure) -----------------------------------------------


def test_plan_runs_cover_request_exactly():
    for lo, hi in [(0, ROWS), (37, 301), (5, 6), (CHUNK_ROWS, 2 * CHUNK_ROWS)]:
        runs = plan_runs(DS, lo, hi, CHUNK_ROWS, 3)
        assert runs[0][1] == lo and runs[-1][2] == hi
        for (_, a_lo, a_hi), (_, b_lo, _) in zip(runs, runs[1:]):
            assert a_hi == b_lo  # contiguous, in row order
        for owner, r_lo, r_hi in runs:
            assert r_lo < r_hi
            for ci in range(r_lo // CHUNK_ROWS, (r_hi - 1) // CHUNK_ROWS + 1):
                assert chunk_owner(DS, ci, 3) == owner
    assert plan_runs(DS, 10, 10, CHUNK_ROWS, 3) == []


def test_partition_rows_roundtrip():
    rng = np.random.default_rng(3)
    rows = [int(r) for r in rng.integers(0, ROWS, 200)]
    plan = partition_rows(DS, rows, CHUNK_ROWS, 4)
    seen = {}
    for owner, (positions, sub_rows) in plan.items():
        assert len(positions) == len(sub_rows)
        assert sorted(positions) == positions  # original order preserved
        for pos, r in zip(positions, sub_rows):
            assert rows[pos] == r
            assert chunk_owner(DS, r // CHUNK_ROWS, 4) == owner
            seen[pos] = r
    assert len(seen) == len(rows)

    data = _data()
    parts = [
        (positions, data[np.asarray(sub_rows)])
        for positions, sub_rows in plan.values()
    ]
    np.testing.assert_array_equal(
        stitch_window(len(rows), parts), data[np.asarray(rows)]
    )


def test_stitch_hyperslab_is_concat():
    data = _data()
    runs = plan_runs(DS, 10, 500, CHUNK_ROWS, 3)
    parts = [data[lo:hi] for _, lo, hi in runs]
    np.testing.assert_array_equal(stitch_hyperslab(parts), data[10:500])


def test_merge_service_stats_rollup():
    a, b = ServiceStats(), ServiceStats()
    a.completed, a.bytes_served, a.queue_depth = 10, 1000, 2
    b.completed, b.bytes_served, b.queue_depth = 5, 500, 1
    a.cache = {"hits": 8, "misses": 2, "hit_rate": 0.8}
    b.cache = {"hits": 0, "misses": 10, "hit_rate": 0.0}
    merged = merge_service_stats({"dn0": a, "dn1": b})
    assert merged.completed == 15
    assert merged.bytes_served == 1500
    assert merged.queue_depth == 3
    assert merged.cache["hits"] == 8 and merged.cache["misses"] == 12
    assert merged.cache["hit_rate"] == pytest.approx(8 / 20)
    assert set(merged.nodes) == {"dn0", "dn1"}
    assert merged.nodes["dn0"]["completed"] == 10


# -- end-to-end: front node over data-node subprocesses ------------------------


@pytest.fixture(scope="module")
def static_cluster(tmp_path_factory):
    """One fully-written run file served by a 2-node cluster, plus the
    single-process reference broker over the same file."""
    tmp = tmp_path_factory.mktemp("shard")
    path = str(tmp / "run.th5")
    data = _data()
    _build_run(path, data)
    fn = ServiceFrontNode.spawn(path, 2, str(tmp / "nodes"))
    ref = DataService(path, ServiceConfig(n_workers=2))
    yield fn, ref, data
    ref.close()
    fn.close()


def test_hyperslab_bit_identity(static_cluster):
    fn, ref, data = static_cluster
    cases = [
        (0, ROWS, None),              # whole dataset (multi-owner fan-out)
        (37, 301, (2, 9)),            # unaligned + column slice
        (CHUNK_ROWS, CHUNK_ROWS, None),  # exactly one chunk (pass-through)
        (5, 10, None),                # sub-chunk
    ]
    for row_start, n_rows, cols in cases:
        req = HyperslabQuery(DS, row_start, n_rows, cols=cols)
        got = fn.request("c", req).value
        want = ref.request("c", req).value
        np.testing.assert_array_equal(got, want)
        assert got.dtype == want.dtype


def test_window_bit_identity(static_cluster):
    fn, ref, data = static_cluster
    rng = np.random.default_rng(11)
    rows = tuple(int(r) for r in rng.integers(0, ROWS, 123))
    req = WindowQuery(DS, rows)
    np.testing.assert_array_equal(
        fn.request("c", req).value, ref.request("c", req).value
    )


def test_query_bit_identity(static_cluster):
    fn, ref, data = static_cluster
    for pred, row_start, n_rows in [
        (col(0) > 0.5, 0, None),
        ((col(1) > 0.0) & (abs(col(2)) < 1.0), 17, 500),
        (~(col(3) > 10.0), 0, ROWS),  # matches everything
    ]:
        req = QueryRequest(DS, pred, row_start=row_start, n_rows=n_rows)
        got = fn.request("c", req).value
        want = ref.request("c", req).value
        np.testing.assert_array_equal(got.rows, want.rows)
        np.testing.assert_array_equal(got.mask, want.mask)
        np.testing.assert_array_equal(got.index, want.index)
        assert got.row_start == want.row_start
        assert got.n_chunks == want.n_chunks
        assert (
            got.chunks_pruned + got.chunks_decoded
            == want.chunks_pruned + want.chunks_decoded
        )


def test_stats_rollup_and_wire_front(static_cluster, tmp_path):
    """The cluster serves the ordinary wire protocol through one socket,
    and a StatsQuery answers with every node's partial under .nodes."""
    fn, ref, data = static_cluster
    server = ServiceServer(fn, str(tmp_path / "sn.sock"))
    cli = RemoteDataService(server.address)
    try:
        req = HyperslabQuery(DS, 3, 333)
        np.testing.assert_array_equal(cli.request("w", req).value, data[3:336])
        st = cli.request("w", StatsQuery()).value
        assert set(st.nodes) == {"dn0", "dn1"}
        assert st.completed >= 2
        assert sum(n["completed"] for n in st.nodes.values()) == st.completed
    finally:
        cli.close()
        server.close()


@pytest.fixture()
def live_cluster(tmp_path):
    """A writable run file (chunks appended DURING the test) behind a
    2-node cluster with a fast fan-out index poll."""
    path = str(tmp_path / "live.th5")
    f = TH5File.create(path)
    meta = f.create_chunked_dataset(DS, (ROWS, COLS), "<f4", CHUNK_ROWS)
    f.commit()
    fn = ServiceFrontNode.spawn(path, 2, str(tmp_path / "nodes"), poll_s=0.05)
    yield fn, f, meta
    fn.close()
    f.close()


def _append(f, meta, data, lo_chunk, hi_chunk):
    for ci in range(lo_chunk, hi_chunk):
        arr = data[ci * CHUNK_ROWS : (ci + 1) * CHUNK_ROWS]
        payload, raw_n, raw_crc, stored_crc, cid = _codecs.encode_chunk(_CODEC, arr)
        f.append_chunk(
            meta, payload, raw_nbytes=raw_n, raw_crc32=raw_crc,
            stored_crc32=stored_crc, codec_id=cid,
        )
    f.commit()


def test_subscribe_fan_in_bit_identity(live_cluster):
    """Every committed chunk arrives exactly once, in chunk-index order,
    with SN-renumbered seq, bit-identical rows — pre-committed chunks and
    chunks committed live (seen by the data nodes via the index poll)."""
    fn, f, meta = live_cluster
    data = _data(seed=23)
    _append(f, meta, data, 0, 4)  # committed before the subscribe
    sub = fn.subscribe("viewer", SubscribeRequest(DS))
    try:
        got = [sub.get(timeout=30.0) for _ in range(4)]
        _append(f, meta, data, 4, N_CHUNKS)  # live, while subscribed
        got += [sub.get(timeout=30.0) for _ in range(N_CHUNKS - 4)]
        assert [g.chunk_index for g in got] == list(range(N_CHUNKS))
        assert [g.seq for g in got] == list(range(N_CHUNKS))
        assert all(g.dropped == 0 for g in got)
        for g in got:
            lo = g.chunk_index * CHUNK_ROWS
            assert g.row_start == lo
            np.testing.assert_array_equal(g.rows, data[lo : lo + CHUNK_ROWS])
    finally:
        sub.close()
    assert sub.get(timeout=10.0) is None  # clean end-of-stream sentinel


def test_subscribe_windowed_fan_in(live_cluster):
    """A row-windowed fan-in delivers exactly the intersecting chunks (the
    indexes both sides predict from chunk_rows), clipped bit-identically."""
    fn, f, meta = live_cluster
    data = _data(seed=29)
    _append(f, meta, data, 0, N_CHUNKS)
    window = (CHUNK_ROWS * 2 + 5, CHUNK_ROWS * 7 - 3)
    wanted = [
        ci for ci in range(N_CHUNKS)
        if ci * CHUNK_ROWS < window[1] and (ci + 1) * CHUNK_ROWS > window[0]
    ]
    sub = fn.subscribe("viewer", SubscribeRequest(DS, rows=window))
    try:
        got = [sub.get(timeout=30.0) for _ in range(len(wanted))]
        assert [g.chunk_index for g in got] == wanted
        for g in got:
            lo = max(g.chunk_index * CHUNK_ROWS, window[0])
            hi = min((g.chunk_index + 1) * CHUNK_ROWS, window[1])
            assert g.row_start == lo
            np.testing.assert_array_equal(g.rows, data[lo:hi])
    finally:
        sub.close()


@pytest.mark.chaos
def test_dn_death_mid_request_is_retryable(tmp_path):
    """Killing a data node turns in-flight/following requests touching its
    partition into typed RetryableError — never a hang, never an untyped
    failure — while single-owner requests for surviving nodes still work."""
    path = str(tmp_path / "run.th5")
    data = _data()
    _build_run(path, data)
    fn = ServiceFrontNode.spawn(path, 2, str(tmp_path / "nodes"))
    try:
        np.testing.assert_array_equal(
            fn.request("c", HyperslabQuery(DS, 0, ROWS)).value, data
        )
        victim = fn.handles[1]
        victim.kill()
        with pytest.raises(RetryableError, match="data node 1"):
            fn.request("c", HyperslabQuery(DS, 0, ROWS))
        # chunks wholly owned by the survivor keep serving
        survivor_chunk = next(
            ci for ci in range(N_CHUNKS) if chunk_owner(DS, ci, 2) == 0
        )
        lo = survivor_chunk * CHUNK_ROWS
        np.testing.assert_array_equal(
            fn.request("c", HyperslabQuery(DS, lo, CHUNK_ROWS)).value,
            data[lo : lo + CHUNK_ROWS],
        )
    finally:
        fn.close()


@pytest.mark.chaos
def test_dn_death_fails_subscription_typed(tmp_path):
    path = str(tmp_path / "run.th5")
    data = _data()
    _build_run(path, data)
    fn = ServiceFrontNode.spawn(path, 2, str(tmp_path / "nodes"))
    try:
        sub = fn.subscribe("viewer", SubscribeRequest(DS))
        first = sub.get(timeout=30.0)
        assert first is not None
        fn.handles[1].kill()
        with pytest.raises(RetryableError, match="data node 1"):
            while True:
                if sub.get(timeout=30.0) is None:
                    raise AssertionError("stream ended without the typed error")
    finally:
        fn.close()
