"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ops import mha
from repro.kernels.attention.ref import attention_ref
from repro.kernels.pack.linear import pack_grids, pack_grids_ref
from repro.kernels.ssd.chunk import ssd_chunk
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_chunk_ref
from repro.kernels.stencil.jacobi import jacobi_sweep, residual
from repro.kernels.stencil.ref import jacobi_sweep_ref, residual_ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-4)


# -- flash attention ---------------------------------------------------------------


@pytest.mark.parametrize(
    "BH,S,D,window", [(4, 128, 64, 0), (2, 256, 128, 0), (2, 256, 64, 64), (3, 100, 32, 0), (1, 64, 256, 16)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(BH, S, D, window, dtype):
    q = jax.random.normal(KEY, (BH, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, D), dtype)
    got = flash_attention(q, k, v, window=window, blk_q=64, blk_k=64, interpret=True)
    want = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_mha_gqa_expansion_matches_ref():
    B, S, H, KV, Dh = 2, 64, 8, 2, 32
    q = jax.random.normal(KEY, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, KV, Dh), jnp.float32)
    got = mha(q, k, v, interpret=True)
    want = mha(q, k, v, use_ref=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_matches_model_attention_path():
    """Kernel agrees with the XLA chunked-attention used by the models."""
    from repro.models.attention import _attend

    B, S, H, Dh = 2, 128, 4, 64
    q = jax.random.normal(KEY, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, H, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, H, Dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    want = _attend(q, k, v, pos, pos, window=0)
    got = mha(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


# -- SSD chunk ----------------------------------------------------------------------


@pytest.mark.parametrize("B,Q,H,P,N", [(2, 64, 8, 16, 32), (1, 128, 4, 32, 64), (2, 32, 16, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_matches_ref(B, Q, H, P, N, dtype):
    k = jax.random.fold_in(KEY, 10)
    x = jax.random.normal(k, (B, Q, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, Q, H))) * 0.1
    da = -dt * jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)) * 0.2)
    b = jax.random.normal(jax.random.fold_in(k, 3), (B, Q, N), dtype) * 0.3
    c = jax.random.normal(jax.random.fold_in(k, 4), (B, Q, N), dtype) * 0.3
    s_in = jax.random.normal(jax.random.fold_in(k, 5), (B, H, P, N)) * 0.1
    got_y, got_s = ssd_chunk(x, da, dt, b, c, s_in, hb=4, interpret=True)
    want_y, want_s = ssd_chunk_ref(x, da, dt, b, c, s_in)
    np.testing.assert_allclose(np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=3e-2 if dtype == jnp.bfloat16 else 3e-5, rtol=3e-2 if dtype == jnp.bfloat16 else 3e-5)


def test_ssd_scan_matches_model_ssd():
    """Full-sequence kernel scan == the model's chunked jnp implementation."""
    from repro.models.ssd import ssd_chunked

    B, S, H, P, N = 2, 128, 4, 16, 32
    k = jax.random.fold_in(KEY, 20)
    x = jax.random.normal(k, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)) * 0.2)
    b = jax.random.normal(jax.random.fold_in(k, 3), (B, S, N)) * 0.3
    c = jax.random.normal(jax.random.fold_in(k, 4), (B, S, N)) * 0.3
    y_kernel, s_kernel = ssd_scan(x, dt, A, b, c, chunk=64, interpret=True)
    y_model, s_model = ssd_chunked(
        x, dt, A, b.reshape(B, S, 1, N), c.reshape(B, S, 1, N)
    )
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_kernel), np.asarray(s_model), atol=2e-4, rtol=2e-4)


# -- stencil ------------------------------------------------------------------------


@pytest.mark.parametrize("G,n", [(4, 16), (2, 32), (8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("omega", [1.0, 1.7])
def test_jacobi_sweep_matches_ref(G, n, dtype, omega):
    p = jax.random.normal(KEY, (G, n + 2, n + 2), dtype)
    f = jax.random.normal(jax.random.fold_in(KEY, 1), (G, n, n), dtype)
    got = jacobi_sweep(p, f, h2=0.01, omega=omega, interpret=True)
    want = jacobi_sweep_ref(p, f, h2=0.01, omega=omega)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("G,n", [(4, 16), (1, 32)])
def test_residual_matches_ref(G, n):
    p = jax.random.normal(KEY, (G, n + 2, n + 2), jnp.float32)
    f = jax.random.normal(jax.random.fold_in(KEY, 2), (G, n, n), jnp.float32)
    got = residual(p, f, h2=0.25, interpret=True)
    want = residual_ref(p, f, h2=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_jacobi_converges_on_poisson():
    """Sanity: repeated sweeps reduce the residual on a 1-grid problem."""
    n = 32
    f = jnp.zeros((1, n, n), jnp.float32)
    p = jnp.zeros((1, n + 2, n + 2), jnp.float32)
    p = p.at[:, 0, :].set(1.0)  # Dirichlet boundary in the halo
    r0 = float(jnp.abs(residual(p, f, h2=1.0, interpret=True)).mean())
    for _ in range(50):
        interior = jacobi_sweep(p, f, h2=1.0, interpret=True)
        p = p.at[:, 1:-1, 1:-1].set(interior)
    r1 = float(jnp.abs(residual(p, f, h2=1.0, interpret=True)).mean())
    assert r1 < r0 * 0.2


# -- pack ---------------------------------------------------------------------------


@pytest.mark.parametrize("G,n", [(4, 16), (2, 8), (1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_pack_grids_matches_ref(G, n, dtype):
    if dtype == jnp.int32:
        p = jax.random.randint(KEY, (G, n + 2, n + 2), 0, 1000, dtype)
    else:
        p = jax.random.normal(KEY, (G, n + 2, n + 2), dtype)
    got = pack_grids(p, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(pack_grids_ref(p)))
