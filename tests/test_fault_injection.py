"""Fault injection against the chunked read path (DecodePipeline).

Every corruption mode a deployed reader can meet — bit-flipped stored chunk
bytes, truncated files, corrupted index JSON, lying chunk records, short
kernel reads — must either surface as a :class:`CorruptFileError` that
*names the offending chunk* (``verify=True``) or, for the unverified fast
path, must at minimum never be laundered through the decoded-chunk cache
into a later verified read.  docs/FORMAT.md §"Integrity verification
summary" is the contract under test.
"""

import os

import numpy as np
import pytest

from repro.core.aggregation import AggregationConfig, ChunkPipeline, DecodePipeline
from repro.core.codecs import encode_chunk, get_codec
from repro.core.container import READ_COUNTER, CorruptFileError, TH5File


def _write_chunked(path, data, chunk_rows, codec, name="/d", pipeline=False):
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset(name, data.shape, data.dtype, chunk_rows, codec)
        if pipeline:
            with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
                pipe.write(meta, data)
        else:
            f.write_chunked(meta, data)
        f.commit()
        return [(c.offset, c.nbytes) for c in meta.chunks]


def _flip_bytes(path, offset, n=8):
    with open(path, "r+b") as fh:
        fh.seek(offset)
        orig = fh.read(n)
        fh.seek(offset)
        fh.write(bytes(b ^ 0xFF for b in orig))


# -- bit-flipped stored chunk bytes --------------------------------------------


@pytest.mark.parametrize("codec", ["none", "zlib", "shuffle+zlib", "int8-blockq"])
def test_bitflip_names_offending_chunk_under_verify(tmp_path, codec):
    """Flipping bytes inside chunk 2's stored extent makes every verified
    read raise a stored-CRC error that names chunk 2 — for every codec
    (the stored CRC is checked *before* decode, so even a corrupted DEFLATE
    stream fails cleanly, not inside zlib)."""
    rng = np.random.default_rng(0)
    data = (rng.integers(0, 64, (64, 8)) / 64).astype(np.float32)
    path = str(tmp_path / f"bf_{codec.replace('+', '_')}.th5")
    chunks = _write_chunked(path, data, 16, codec)
    _flip_bytes(path, chunks[2][0] + chunks[2][1] // 2)
    with TH5File.open(path) as f:
        with pytest.raises(CorruptFileError, match="chunk 2 of /d"):
            f.read("/d", verify=True)
        # partial verified reads not touching chunk 2 still succeed
        got = np.empty((16, 8), np.float32)
        f._gather_rows_chunked("/d", f.meta("/d"), 0, 16, got, verify=True)
        if get_codec(codec).lossless:
            np.testing.assert_array_equal(got, data[:16])
        else:  # int8-blockq: within the stored-scale tolerance
            from repro.core.codecs import Int8BlockQCodec

            assert np.abs(got - data[:16]).max() <= Int8BlockQCodec.tolerance(data[:16])


def test_multiple_corrupt_chunks_fail_cleanly_and_pipeline_survives(tmp_path):
    """Two corrupt chunks inside one pipelined read: the first (in chunk
    order) is the one reported; in-flight workers for the second are
    retrieved, not leaked; and the shared decode pool stays usable for
    later reads on the same file."""
    rng = np.random.default_rng(9)
    data = (rng.integers(0, 64, (64, 8)) / 64).astype(np.float32)
    path = str(tmp_path / "multi.th5")
    chunks = _write_chunked(path, data, 8, "zlib")
    for ci in (2, 5):
        _flip_bytes(path, chunks[ci][0] + 2)
    with TH5File.open(path) as f:
        for _ in range(2):  # error path must be repeatable, not poison the pool
            with pytest.raises(CorruptFileError, match="chunk 2 of /d"):
                f.read("/d", verify=True)
        # untouched region still reads verified through the same pipeline
        out = np.empty((16, 8), np.float32)
        f._gather_rows_chunked("/d", f.meta("/d"), 0, 16, out, verify=True)
        np.testing.assert_array_equal(out, data[:16])


def test_lying_raw_crc_caught_after_decode(tmp_path):
    """A chunk record whose raw_crc32 doesn't match the decoded payload
    (index bitrot / writer bug): the stored stream inflates fine, so only
    the post-decode raw-CRC check can catch it — and it names the chunk."""
    data = np.arange(128, dtype=np.float32).reshape(32, 4)
    path = str(tmp_path / "lying.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 16, "zlib")
        codec = get_codec("zlib")
        for ci, lo in enumerate(range(0, 32, 16)):
            payload, raw_n, raw_crc, stored_crc, cid = encode_chunk(codec, data[lo : lo + 16])
            f.append_chunk(
                meta,
                payload,
                raw_nbytes=raw_n,
                raw_crc32=raw_crc ^ (0xDEAD if ci == 1 else 0),  # chunk 1 lies
                stored_crc32=stored_crc,
                codec_id=cid,
            )
        f.commit()
    with TH5File.open(path) as f:
        with pytest.raises(CorruptFileError, match="payload CRC mismatch on chunk 1 of /d"):
            f.read("/d", verify=True)
        np.testing.assert_array_equal(f.read("/d", verify=False), data)  # unverified: readable


# -- truncation ----------------------------------------------------------------


def test_truncated_file_names_offending_chunk(tmp_path):
    """The file gets truncated inside the last chunk's extent *under* an
    open reader (torn copy / concurrent writer crash): the fetch hits EOF
    mid-extent and the error names the chunk instead of a bare offset.
    (Truncation below the index offset makes the file unopenable outright —
    the superblock points past EOF and open() raises; that path is covered
    by the index-corruption tests.)"""
    rng = np.random.default_rng(1)
    data = (rng.integers(0, 64, (64, 8)) / 64).astype(np.float32)
    path = str(tmp_path / "trunc.th5")
    chunks = _write_chunked(path, data, 16, "zlib")
    with TH5File.open(path) as f:  # index loaded before the truncation
        os.truncate(path, chunks[3][0] + chunks[3][1] // 2)
        with pytest.raises(CorruptFileError, match="chunk 3 of /d"):
            f.read("/d", verify=True)
        with pytest.raises(CorruptFileError, match="chunk 3 of /d"):
            f.read_rows("/d", 48, 16)  # unverified decode path fetches too
        np.testing.assert_array_equal(f.read_rows("/d", 0, 48), data[:48])
    # after the truncation the live index itself is gone → unopenable
    with pytest.raises(CorruptFileError):
        TH5File.open(path)


# -- corrupted metadata --------------------------------------------------------


def test_corrupt_index_json_rejected_at_open(tmp_path):
    data = np.zeros((32, 4), np.float32)
    path = str(tmp_path / "idx.th5")
    _write_chunked(path, data, 16, "zlib")
    with TH5File.open(path) as f:
        pass  # sanity: opens before corruption
    sb = open(path, "rb").read(512)
    import struct

    _, _, _, index_off, _, _, _, _, _ = struct.unpack_from("<4sIIQQQQdI", sb, 0)
    _flip_bytes(path, index_off + 16)  # inside the JSON payload, past the CRC header
    with pytest.raises(CorruptFileError, match="index CRC mismatch"):
        TH5File.open(path)


def test_corrupt_superblock_rejected_at_open(tmp_path):
    path = str(tmp_path / "sb.th5")
    _write_chunked(path, np.zeros((8, 4), np.float32), 4, "none")
    _flip_bytes(path, 8, 4)  # block_size field → CRC mismatch
    with pytest.raises(CorruptFileError, match="superblock CRC mismatch"):
        TH5File.open(path)


# -- cache laundering ----------------------------------------------------------


@pytest.mark.parametrize("codec", ["none", "int8-blockq"])
def test_unverified_cache_never_launders_corruption(tmp_path, codec):
    """An unverified multi-chunk read (LOD playback) happily decodes and
    caches corrupted bytes (codecs where corruption decodes silently).  A
    later verify=True read must re-fetch and raise — the poisoned cache
    entry must never satisfy it.  Exercises the pipelined (multi-job) path,
    not just the single-chunk inline one."""
    rng = np.random.default_rng(2)
    data = (rng.random((64, 8)) - 0.5).astype(np.float32)
    path = str(tmp_path / f"laund_{codec}.th5")
    chunks = _write_chunked(path, data, 8, codec)
    _flip_bytes(path, chunks[5][0], 4)
    with TH5File.open(path) as f:
        # scatter gather decodes + caches every chunk (for `none` too — the
        # row-gather path stages decodes through the cache, unlike the
        # contiguous fast path)
        got = f.read_row_indices("/d", range(64))
        assert f.chunk_cache.stats()["entries"] == 8
        assert not np.array_equal(got[40:48], data[40:48])  # corruption landed
        with pytest.raises(CorruptFileError, match="chunk 5 of /d"):
            f.read("/d", verify=True)
        # the poisoned entry still serves unverified reads (same bytes) —
        # corruption detection is verify's job, laundering is the bug
        np.testing.assert_array_equal(f.read_row_indices("/d", range(64)), got)


def test_verified_read_repopulates_cache_with_verified_decode(tmp_path):
    """verify=True bypasses cache *hits* but its (checked) decode does
    refresh the cache — later unverified reads serve verified bytes."""
    data = np.arange(256, dtype=np.float32).reshape(64, 4)
    path = str(tmp_path / "fresh.th5")
    _write_chunked(path, data, 16, "shuffle+zlib")
    with TH5File.open(path) as f:
        f.read("/d", verify=False)
        s0 = f.chunk_cache.stats()
        f.read("/d", verify=True)  # no cache gets, 4 fresh decodes + puts
        s1 = f.chunk_cache.stats()
        assert s1["misses"] == s0["misses"]  # verified path never polled the cache
        np.testing.assert_array_equal(f.read("/d"), data)


# -- short kernel reads --------------------------------------------------------


def test_short_preadv_resumes_through_decode_pipeline(tmp_path, monkeypatch):
    """os.preadv returning short counts (network FS, signals) must be
    resumed transparently by every fetch path — pipelined decode fetches,
    the none-codec direct scatter, and single-chunk inline decodes."""
    rng = np.random.default_rng(3)
    data = (rng.integers(0, 64, (64, 8)) / 64).astype(np.float32)
    raw = rng.integers(0, 255, (64, 8), dtype=np.uint8)
    path = str(tmp_path / "short.th5")
    with TH5File.create(path) as f:
        mz = f.create_chunked_dataset("/z", data.shape, "<f4", 8, "shuffle+zlib")
        f.write_chunked(mz, data)
        mn = f.create_chunked_dataset("/n", raw.shape, "<u1", 8, "none")
        f.write_chunked(mn, raw)
        f.commit()

    real = os.preadv

    def short_preadv(fd_, bufs, off):
        first = bufs[0]
        if len(first) > 5:  # cap every syscall at 5 bytes
            first = first[:5]
        return real(fd_, [first], off)

    with TH5File.open(path) as f:
        monkeypatch.setattr(os, "preadv", short_preadv)
        READ_COUNTER.reset()
        np.testing.assert_array_equal(f.read("/z", verify=True), data)  # pipelined fetches
        np.testing.assert_array_equal(f.read("/n"), raw)  # direct scatter
        got = f.read_rows("/z", 4, 8)  # straddles chunks 0|1
        np.testing.assert_array_equal(got, data[4:12])
        syscalls, nbytes = READ_COUNTER.snapshot()
        assert syscalls > nbytes / 5 - 1  # genuinely resumed 5 bytes at a time


def test_decode_pipeline_standalone_on_missing_chunks(tmp_path):
    """DecodePipeline surfaces incomplete writes (sparse chunk list) as
    CorruptFileError naming the first missing chunk."""
    path = str(tmp_path / "miss.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", (32, 4), "<f4", 8, "zlib")
        payload, raw_n, rc, sc, cid = encode_chunk(get_codec("zlib"), np.zeros((8, 4), np.float32))
        f.append_chunk(meta, payload, raw_nbytes=raw_n, raw_crc32=rc, stored_crc32=sc, codec_id=cid)
        with DecodePipeline(f) as pipe:
            with pytest.raises(CorruptFileError, match="chunk 1 of /d missing"):
                pipe.decode_chunks("/d", meta, [0, 1, 2])
            out = np.empty((8, 4), np.float32)
            assert pipe.gather_rows("/d", meta, 0, 8, out) == out.nbytes
            np.testing.assert_array_equal(out, np.zeros((8, 4), np.float32))


# -- lying / corrupt / stale chunk statistics (predicate pushdown) -------------
#
# Stats are advisory: the planner may prune a chunk only on a validated
# proof.  Any record that fails validation — structural garbage, a stale
# crc echo, internally inconsistent bounds — must degrade that chunk to
# decode-and-filter, name it in ``QueryResult.invalid_stats``, and never
# change the rows returned.


def _query_with_oracle(f, pred, n=128):
    from repro.core.query import evaluate_mask

    res = f.query("/d", pred)
    full = f.read("/d")
    want = evaluate_mask(pred, full.reshape(n, -1))
    assert np.array_equal(res.mask, want)
    assert res.rows.tobytes() == np.ascontiguousarray(full[want]).tobytes()
    return res


def _stats_victim(tmp_path, name):
    rng = np.random.default_rng(17)
    data = rng.normal(size=(128, 8)).astype("<f4")
    path = str(tmp_path / f"{name}.th5")
    _write_chunked(path, data, 32, "zlib")
    return path


def test_corrupt_stats_record_degrades_to_full_filter(tmp_path):
    """Structurally-garbage stats persisted in the index: the chunk is
    decoded anyway, named in invalid_stats, and rows are unchanged."""
    from repro.core.query import ChunkStats, col

    path = _stats_victim(tmp_path, "corrupt_stats")
    with TH5File.open(path, mode="r+") as f:
        f.meta("/d").chunks[1].stats = ChunkStats.from_json({"not": "stats"})
        f._dirty = True
        f.commit()
    with TH5File.open(path) as f:
        res = _query_with_oracle(f, col(0) > 1e9)
        assert res.invalid_stats == (1,)
        assert res.chunks_decoded == 1 and res.chunks_pruned == 3
        assert res.n_matches == 0


def test_stale_generation_stats_detected_by_crc_echo(tmp_path):
    """Index-surgery / stale-generation fault: chunk 0 carries chunk 3's
    stats record.  The crc echo no longer matches chunk 0's raw CRC, so
    the record is distrusted — even though it is internally consistent."""
    from repro.core.query import col

    path = _stats_victim(tmp_path, "stale_stats")
    with TH5File.open(path, mode="r+") as f:
        chunks = f.meta("/d").chunks
        assert chunks[3].stats is not None
        chunks[0].stats = chunks[3].stats
        f._dirty = True
        f.commit()
    with TH5File.open(path) as f:
        rec = f.meta("/d").chunks[0]
        assert not rec.stats.valid_for(32, 8, rec.raw_crc32)
        res = _query_with_oracle(f, col(2) > 1e9)
        assert res.invalid_stats == (0,)
        assert res.chunks_decoded == 1 and res.chunks_pruned == 3


@pytest.mark.parametrize(
    "lie",
    [
        "min_above_max",  # lo > hi
        "counts_exceed_chunk",  # nan+finite > chunk size
        "wrong_n_cols",  # claims a different row width
        "nan_bound",  # NaN smuggled into a bound
    ],
)
def test_adversarially_lying_stats_never_skip_matches(tmp_path, lie):
    """Internally-inconsistent stats records — every detectable category of
    lie — must fail validation and fall back to decode-and-filter, so a
    lying record can never make the planner skip a matching chunk."""
    from repro.core.query import ChunkStats, col

    path = _stats_victim(tmp_path, f"lie_{lie}")
    with TH5File.open(path, mode="r+") as f:
        rec = f.meta("/d").chunks[2]
        g = len(rec.stats.mins)
        fields = dict(
            crc_echo=rec.raw_crc32, n_cols=8,
            mins=(-1.0,) * g, maxs=(1.0,) * g,
            nan_counts=(0,) * g, finite_counts=(32 * 8 // g,) * g,
        )
        if lie == "min_above_max":
            fields["mins"] = (2.0,) * g
        elif lie == "counts_exceed_chunk":
            fields["nan_counts"] = (10**6,) * g
        elif lie == "wrong_n_cols":
            fields["n_cols"] = 4
        elif lie == "nan_bound":
            fields["maxs"] = (float("nan"),) * g
        rec.stats = ChunkStats(**fields)
        assert not rec.stats.valid_for(32, 8, rec.raw_crc32)
        f._dirty = True
        f.commit()
    with TH5File.open(path) as f:
        # a predicate the lying bounds would have pruned
        res = _query_with_oracle(f, col(0) > 1e9)
        assert 2 in res.invalid_stats
        assert res.chunks_decoded >= 1 and res.n_matches == 0
        # and a broad predicate: every true match still comes back
        res = _query_with_oracle(f, col(0) > -1e9)
        assert res.n_matches == 128


def test_stats_stripped_index_still_queries(tmp_path):
    """A v2 index written without stats records (older writer) stays fully
    readable: query degrades to decode-everything with empty invalid_stats
    — absence of stats is not a fault."""
    from repro.core.query import col

    path = _stats_victim(tmp_path, "no_stats")
    with TH5File.open(path, mode="r+") as f:
        for rec in f.meta("/d").chunks:
            rec.stats = None
        f._dirty = True
        f.commit()
    with TH5File.open(path) as f:
        assert all(rec.stats is None for rec in f.meta("/d").chunks)
        res = _query_with_oracle(f, col(0) > 1e9)
        assert res.invalid_stats == ()
        assert res.chunks_pruned == 0 and res.chunks_decoded == 4
