"""Sharding rules: spec resolution, dedupe, divisibility fixes, MoE modes."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_resolve_spec_basics():
    rules = {"batch": ("pod", "data"), "heads": "model", "seq": None}
    assert sharding.resolve_spec(("batch", "seq", "heads"), rules) == P(("pod", "data"), None, "model")


def test_resolve_spec_dedupes_first_wins():
    rules = {"cache_seq": "model", "act_kv_heads": "model", "batch": "data"}
    spec = sharding.resolve_spec(("batch", "cache_seq", "act_kv_heads", None), rules)
    assert spec == P("data", "model", None, None)
    # tuple entries drop used members
    rules2 = {"a": ("data", "model"), "b": ("model",)}
    assert sharding.resolve_spec(("b", "a"), rules2) == P("model", ("data",))


def test_fix_specs_drops_nondivisible():
    mesh = _FakeMesh({"data": 2, "model": 4})  # fix_specs only reads .shape
    specs = {"x": P(None, "model"), "y": P("data", None), "z": P(("data", "model"))}
    sds = {
        "x": jax.ShapeDtypeStruct((4, 6), np.float32),  # 6 % 4 != 0 → drop
        "y": jax.ShapeDtypeStruct((8, 2), np.float32),  # ok
        "z": jax.ShapeDtypeStruct((4,), np.float32),  # 4 % 8 → prefix ("data",)
    }
    fixed = sharding.fix_specs(mesh, specs, sds)
    assert fixed["x"] == P(None, None)
    assert fixed["y"] == P("data", None)
    assert fixed["z"] == P(("data",))


def test_moe_rules_ep_vs_tp():
    mesh = make_mesh((1, 1), ("data", "model"))

    class M:  # 16-way model axis stand-ins
        pass

    granite = get_config("granite-moe-1b-a400m")
    mixtral = get_config("mixtral-8x7b")
    mesh16 = make_mesh((1, 1), ("data", "model"))
    # emulate a 16-wide model axis via the production mesh shape logic
    r_g = sharding._moe_rules(_FakeMesh({"model": 16}), granite, ("data",))
    r_m = sharding._moe_rules(_FakeMesh({"model": 16}), mixtral, ("data",))
    assert r_g["experts"] == "model" and r_g["expert_ff"] is None  # EP (32 % 16 == 0)
    assert r_m["experts"] is None and r_m["expert_ff"] == "model"  # ff-TP (8 % 16 != 0)


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_constrain_noop_without_context():
    x = jax.numpy.ones((4, 4))
    y = sharding.constrain(x, ("batch", "seq"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_decode_long_rules_seq_parallel():
    mesh = make_mesh((1, 1), ("data", "model"))
    r = sharding.decode_long_rules(mesh, None)
    assert r["batch"] is None
    assert r["cache_seq"] == "data"


def test_zero3_rules_no_tensor_parallelism():
    mesh = make_mesh((1, 1), ("data", "model"))
    r = sharding.train_rules_zero3(mesh, None)
    assert r["heads"] is None and r["ff"] is None and r["vocab"] is None
    assert r["embed_fsdp"] == ("data", "model")
    assert r["batch"] == ("data", "model")
