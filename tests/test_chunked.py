"""Chunked + compressed TH5 datasets: round-trip properties, the overlapped
filter pipeline, variable-length file domains, LRU chunk cache, and the
checkpoint codec policy (docs/FORMAT.md is the layout spec)."""

import os

import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.core.aggregation import (
    COPY_COUNTER,
    AggregationConfig,
    ChunkPipeline,
    CollectiveWriter,
    WriteRequest,
    assign_file_domains,
)
from repro.core.checkpoint import CheckpointManager, CodecPolicy
from repro.core.codecs import (
    CODEC_NONE,
    CODEC_ZLIB,
    Int8BlockQCodec,
    encode_chunk,
    get_codec,
)
from repro.core.container import TH5Error, TH5File


def _roundtrip(tmp_path, data, chunk_rows, codec, name="rt.th5"):
    path = str(tmp_path / name)
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, data.dtype, chunk_rows, codec)
        f.write_chunked(meta, data)
        f.commit()
    with TH5File.open(path) as f:
        return f.read("/d", verify=True), f.meta("/d")


# -- round-trip properties (hypothesis via the tests/_hyp shim) ----------------


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=70),
    cols=st.integers(min_value=1, max_value=9),
    chunk_rows=st.integers(min_value=1, max_value=80),
    codec=st.sampled_from(["none", "zlib", "zlib:6", "shuffle+zlib", "shuffle+zlib:6"]),
    dtype=st.sampled_from(["<f4", "<f8", "<i4", "<u1"]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_lossless_roundtrip_bitexact(tmp_path, rows, cols, chunk_rows, codec, dtype, seed):
    """Any (shape, chunk size, lossless codec) combination round-trips
    bit-exact, including chunk_rows > rows and ragged final chunks."""
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    if dt.kind == "f":
        data = (rng.integers(0, 32, (rows, cols)) / 32).astype(dt)
    else:
        data = rng.integers(0, 100, (rows, cols)).astype(dt)
    got, meta = _roundtrip(tmp_path, data, chunk_rows, codec)
    np.testing.assert_array_equal(got, data)
    assert len(meta.chunks) == -(-rows // min(chunk_rows, 80))


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=60),
    cols=st.integers(min_value=1, max_value=7),
    chunk_rows=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_lossy_roundtrip_within_stored_scale_tolerance(tmp_path, rows, cols, chunk_rows, seed):
    rng = np.random.default_rng(seed)
    data = ((rng.random((rows, cols)) - 0.5) * 10).astype(np.float32)
    got, _ = _roundtrip(tmp_path, data, chunk_rows, "int8-blockq")
    assert np.abs(got.astype(np.float64) - data).max() <= Int8BlockQCodec.tolerance(data)


@settings(max_examples=30, deadline=None)
@given(
    n_elems=st.integers(min_value=0, max_value=4096),
    dtype=st.sampled_from(["<f4", "<f8", "<i8", "<u2", "<u1"]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_byte_shuffle_is_a_pure_permutation(n_elems, dtype, seed):
    """shuffle∘unshuffle == identity for any element count × itemsize, and
    the shuffled buffer is byte-for-byte a permutation of the input."""
    from repro.core.codecs import byte_shuffle, byte_unshuffle

    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    raw = rng.integers(0, 256, n_elems * dt.itemsize, dtype=np.uint8).tobytes()
    shuf = byte_shuffle(raw, dt.itemsize)
    assert shuf.nbytes == len(raw)
    np.testing.assert_array_equal(np.sort(shuf), np.sort(np.frombuffer(raw, np.uint8)))
    np.testing.assert_array_equal(byte_unshuffle(shuf.tobytes(), dt.itemsize),
                                  np.frombuffer(raw, np.uint8))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=70),
    cols=st.integers(min_value=1, max_value=9),
    chunk_rows=st.integers(min_value=1, max_value=80),
    dtype=st.sampled_from(["<f4", "<f8"]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_shuffle_zlib_roundtrip_bitexact(tmp_path, rows, cols, chunk_rows, dtype, seed):
    """The shuffle pre-filter stays bit-exact across shape × dtype × chunk
    size, including ragged final chunks and chunk_rows > rows — and the
    written chunks survive the byte-balanced file-domain split (the
    straddling-boundary case is exercised separately below)."""
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    data = ((rng.integers(0, 256, (rows, cols)) / 256) * 8 - 4).astype(dt)
    got, meta = _roundtrip(tmp_path, data, chunk_rows, "shuffle+zlib")
    np.testing.assert_array_equal(got, data)
    assert len(meta.chunks) == -(-rows // min(chunk_rows, 80))


def test_shuffle_zlib_chunks_straddle_file_domain_boundaries(tmp_path):
    """shuffle+zlib chunks through the overlapped pipeline: wildly unequal
    post-filter sizes land across byte-balanced domain boundaries and still
    round-trip bit-exact under verify=True."""
    from repro.core.codecs import CODEC_SHUFFLE_ZLIB

    rng = np.random.default_rng(12)
    parts = []
    for i in range(16):  # alternate smooth (compressible) and noisy chunks
        if i % 2:
            parts.append(np.full((64, 16), float(i), np.float32))
        else:
            parts.append(rng.standard_normal((64, 16)).astype(np.float32))
    data = np.concatenate(parts)
    with TH5File.create(str(tmp_path / "svl.th5")) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 64, "shuffle+zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            fs = pipe.write(meta, data)
        f.commit()
        assert fs.n_chunks == 16
        assert len({c.nbytes for c in meta.chunks}) > 1  # genuinely variable-length
        assert CODEC_SHUFFLE_ZLIB in {c.codec_id for c in meta.chunks}
        np.testing.assert_array_equal(f.read("/d", verify=True), data)


def test_shuffle_uplift_over_plain_zlib_on_f32():
    """Ratio regression: the byte-shuffle pre-filter must compress f32 field
    data at least as well as plain zlib (in practice ~30% better — the
    committed BENCH_io.json `read` section tracks the exact uplift)."""
    rng = np.random.default_rng(7)
    field = (rng.integers(0, 1024, (2048, 64)) / 1024.0).astype(np.float32)
    plain = len(get_codec("zlib").encode(field))
    shuffled = len(get_codec("shuffle+zlib").encode(field))
    assert shuffled <= plain
    assert field.nbytes / shuffled > 1.88  # beats the committed plain-zlib ratio


def test_1d_and_ragged_final_chunk_roundtrip(tmp_path):
    data = np.arange(101, dtype=np.int64)
    got, meta = _roundtrip(tmp_path, data, chunk_rows=16, codec="zlib")
    np.testing.assert_array_equal(got, data)
    assert len(meta.chunks) == 7  # 6 full + 1 ragged
    assert meta.chunks[-1].raw_nbytes == 5 * 8


def test_incompressible_chunks_fall_back_to_none(tmp_path):
    rng = np.random.default_rng(3)
    data = rng.integers(0, 2**63, (64, 4), dtype=np.int64)  # high-entropy
    got, meta = _roundtrip(tmp_path, data, chunk_rows=16, codec="zlib")
    np.testing.assert_array_equal(got, data)
    assert all(c.codec_id == CODEC_NONE for c in meta.chunks)
    assert meta.stored_nbytes == meta.nbytes  # no space overhead

    mixed = np.zeros((64, 4), np.int64)  # all-zero: maximally compressible
    got2, meta2 = _roundtrip(tmp_path, mixed, 16, "zlib", name="rt2.th5")
    np.testing.assert_array_equal(got2, mixed)
    assert all(c.codec_id == CODEC_ZLIB for c in meta2.chunks)
    assert meta2.stored_nbytes < meta2.nbytes


def test_encode_chunk_none_is_zero_copy_view():
    arr = np.arange(32, dtype=np.float32)
    COPY_COUNTER.reset()
    payload, raw_n, raw_crc, stored_crc, cid = encode_chunk(get_codec("none"), arr)
    assert COPY_COUNTER.snapshot() == (0, 0)
    assert isinstance(payload, memoryview) and raw_n == arr.nbytes
    assert raw_crc == stored_crc and cid == CODEC_NONE


# -- partial reads + chunk cache -----------------------------------------------


def test_partial_reads_decode_only_intersecting_chunks(tmp_path):
    rng = np.random.default_rng(4)
    data = (rng.integers(0, 64, (96, 5)) / 64).astype(np.float32)
    path = str(tmp_path / "p.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 16, "zlib")
        f.write_chunked(meta, data)
        f.commit()
    with TH5File.open(path) as f:
        # rows 30..50 straddle chunks 1, 2, 3 → exactly 3 decodes
        np.testing.assert_array_equal(f.read_rows("/d", 30, 20), data[30:50])
        assert f.chunk_cache.stats()["misses"] == 3
        # repeat: all hits, no new decodes
        np.testing.assert_array_equal(f.read_rows("/d", 30, 20), data[30:50])
        s = f.chunk_cache.stats()
        assert s["misses"] == 3 and s["hits"] == 3
        # scatter gather across chunks
        idx = [0, 95, 17, 18, 2]
        np.testing.assert_array_equal(f.read_row_indices("/d", idx), data[idx])
        out = np.empty((4, 5), np.float32)
        f.read_rows_into("/d", 14, 4, out)  # straddles chunks 0|1
        np.testing.assert_array_equal(out, data[14:18])
        with pytest.raises(TH5Error):
            f.read_rows_into("/d", 94, 4, np.empty((4, 5), np.float32))


def test_chunk_cache_lru_eviction(tmp_path):
    data = np.zeros((64, 8), np.float32)
    path = str(tmp_path / "lru.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 8, "zlib")
        f.write_chunked(meta, data)
        f.commit()
    with TH5File.open(path) as f:
        f.chunk_cache.capacity_bytes = 3 * 8 * 8 * 4  # room for 3 decoded chunks
        f.read("/d")  # touches all 8 chunks
        s = f.chunk_cache.stats()
        assert s["entries"] == 3 and s["evictions"] == 5
        assert s["bytes"] <= f.chunk_cache.capacity_bytes


def test_verified_read_never_served_from_unverified_cache(tmp_path):
    """An unverified read (LOD playback) caches its decode; a later
    verify=True read of corrupted bytes must still raise, not return the
    poisoned cache entry."""
    data = np.arange(64, dtype=np.float32).reshape(16, 4)
    path = str(tmp_path / "corrupt.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 8, "none")
        f.write_chunked(meta, data)
        rec = meta.chunks[0]
        f.commit()
    with open(path, "r+b") as fh:  # flip bytes inside chunk 0's extent
        fh.seek(rec.offset)
        fh.write(b"\xff" * 8)
    with TH5File.open(path) as f:
        f.read_row_indices("/d", [0, 1])  # unverified: populates the cache
        assert f.chunk_cache.stats()["entries"] >= 1
        with pytest.raises(Exception, match="CRC"):
            f.read("/d", verify=True)


def test_incomplete_chunked_write_raises_on_read(tmp_path):
    path = str(tmp_path / "inc.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", (32, 4), "<f4", 8, "zlib")
        # write only the first chunk's worth, then try to read everything
        payload, raw_n, rc, sc, cid = encode_chunk(get_codec("zlib"), np.zeros((8, 4), np.float32))
        f.append_chunk(meta, payload, raw_nbytes=raw_n, raw_crc32=rc, stored_crc32=sc, codec_id=cid)
        with pytest.raises(Exception, match="missing"):
            f.read("/d")
        np.testing.assert_array_equal(f.read_rows("/d", 0, 8), np.zeros((8, 4), np.float32))


def test_chunked_rejects_slab_writes_and_seal(tmp_path):
    with TH5File.create(str(tmp_path / "g.th5")) as f:
        meta = f.create_chunked_dataset("/d", (8, 4), "<f4", 4, "zlib")
        with pytest.raises(TH5Error):
            f.write_slab(meta, 0, np.zeros((8, 4), np.float32))
        f.write_chunked(meta, np.zeros((8, 4), np.float32))
        with pytest.raises(TH5Error):
            f.seal_checksum("/d")
        with pytest.raises(TH5Error):
            f.write_chunked(meta, np.zeros((8, 4), np.float32))  # already written


# -- overlapped pipeline + file domains ----------------------------------------


def test_chunk_pipeline_overlaps_encode_with_writes(tmp_path):
    rng = np.random.default_rng(5)
    data = (rng.integers(0, 128, (2048, 64)) / 128).astype(np.float32)
    with TH5File.create(str(tmp_path / "ov.th5")) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 128, "zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            fs = pipe.write(meta, data)
        f.commit()
        assert fs.n_chunks == 16
        assert fs.raw_bytes == data.nbytes
        assert 0 < fs.stored_bytes < data.nbytes
        assert fs.ratio > 1.5
        assert fs.encode_s > 0 and fs.write_s > 0
        np.testing.assert_array_equal(f.read("/d", verify=True), data)


def test_chunk_pipeline_none_codec_is_zero_copy(tmp_path):
    """The PR-1 invariant survives chunking: raw-chunk writes via the
    pipeline's file-domain route never copy payload bytes."""
    rng = np.random.default_rng(6)
    data = rng.integers(0, 255, (1024, 32), dtype=np.uint8)
    with TH5File.create(str(tmp_path / "zc.th5")) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<u1", 100, "none")
        COPY_COUNTER.reset()
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            fs = pipe.write(meta, data)
        assert COPY_COUNTER.snapshot() == (0, 0)
        assert fs.ratio == 1.0 and fs.stored_bytes == data.nbytes
        f.commit()
        np.testing.assert_array_equal(f.read("/d", verify=True), data)


def test_variable_length_chunks_straddle_file_domain_boundaries(tmp_path):
    """Post-filter chunks have wildly unequal sizes; the byte-balanced
    domain split lands mid-sequence (chunk boundaries ≠ domain boundaries)
    and the write must still round-trip."""
    rng = np.random.default_rng(7)
    # alternate incompressible and all-zero chunks → stored sizes ~4096 / ~30
    parts = []
    for i in range(16):
        if i % 2:
            parts.append(np.zeros((64, 16), np.uint8))
        else:
            parts.append(rng.integers(0, 255, (64, 16), dtype=np.uint8))
    data = np.concatenate(parts)
    with TH5File.create(str(tmp_path / "vl.th5")) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<u1", 64, "zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            fs = pipe.write(meta, data)
        f.commit()
        sizes = {c.nbytes for c in meta.chunks}
        assert len(sizes) > 1  # genuinely variable-length
        assert {c.codec_id for c in meta.chunks} == {CODEC_NONE, CODEC_ZLIB}
        np.testing.assert_array_equal(f.read("/d", verify=True), data)
    # the bucketing itself: byte-balanced domains split at request boundaries
    reqs = [WriteRequest(c.offset, bytes(c.nbytes)) for c in meta.chunks]
    domains = assign_file_domains(reqs, 4)
    assert 1 < len(domains) <= 4
    assert sum(len(d) for d in domains) == len(reqs)
    flat = [r.offset for d in domains for r in d]
    assert flat == sorted(flat)
    assert fs.n_chunks == 16


def test_variable_length_requests_through_collective_writer(tmp_path):
    """write_collective with file domains handles variable-length payloads
    (the post-filter shape) — bytes land at their exact offsets."""
    rng = np.random.default_rng(8)
    sizes = [1, 4096, 7, 2000, 64, 512, 3, 9000]
    offs = np.cumsum([0] + sizes[:-1])
    payloads = [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]
    path = str(tmp_path / "vr.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/d", (sum(sizes),), "<u1")
        reqs = [[WriteRequest(meta.offset + int(o), p)] for o, p in zip(offs, payloads)]
        with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=3)) as w:
            stats = w.write_collective(reqs)
        assert stats.bytes_written == sum(sizes)
        f.commit()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/d"), np.concatenate(payloads))


# -- the read-side decode pipeline ---------------------------------------------


def test_decode_pipeline_overlaps_fetch_with_inflate(tmp_path):
    """Cold multi-chunk read: stored bytes of chunk k+1 are preadv-fetched
    while chunk k inflates in the decode pool — both halves show up in the
    per-read FilterStats and the result is bit-exact."""
    rng = np.random.default_rng(13)
    data = (rng.integers(0, 128, (2048, 64)) / 128).astype(np.float32)
    path = str(tmp_path / "dp.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 128, "zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            pipe.write(meta, data)
        f.commit()
    with TH5File.open(path) as f:  # fresh open: cold chunk cache
        f.set_decode_config(AggregationConfig(n_aggregators=4))  # explicit pool width
        got = f.read("/d")
        np.testing.assert_array_equal(got, data)
        rs = f.last_read_stats
        assert rs is not None and rs.n_chunks == 16
        assert rs.raw_bytes == data.nbytes and 0 < rs.stored_bytes < data.nbytes
        assert rs.decode_s > 0 and rs.fetch_s > 0 and rs.wall_s > 0
        # warm read: all cache hits → no decode work in the new stats
        f.read("/d")
        assert f.last_read_stats.n_chunks == 0
        # cumulative stats accumulated both reads
        assert f.read_stats.n_chunks == 16


def test_decode_pipeline_none_codec_read_is_zero_copy(tmp_path):
    """The PR-1/PR-2 invariant holds on the read side: raw-chunk gathers
    scatter straight into the caller's buffer — COPY_COUNTER delta 0 and no
    decode-pool work."""
    rng = np.random.default_rng(14)
    data = rng.integers(0, 255, (1024, 32), dtype=np.uint8)
    path = str(tmp_path / "zr.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<u1", 100, "none")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=4)) as pipe:
            pipe.write(meta, data)
        f.commit()
    with TH5File.open(path) as f:
        COPY_COUNTER.reset()
        out = np.empty_like(data)
        f.read_rows_into("/d", 0, 1024, out)
        assert COPY_COUNTER.snapshot() == (0, 0)
        np.testing.assert_array_equal(out, data)
        assert f.last_read_stats.n_chunks == 0  # fast path bypassed the pool
        assert f.chunk_cache.stats()["entries"] == 0  # and never staged a decode


def test_decode_pipeline_mixed_codec_gather(tmp_path):
    """A gather spanning none- and zlib-coded chunks routes each through its
    own path (direct scatter vs pipeline) within one read."""
    rng = np.random.default_rng(15)
    # alternate incompressible (falls back to none) and all-zero chunks
    parts = [
        rng.integers(0, 2**63, (32, 4), dtype=np.int64) if i % 2 else np.zeros((32, 4), np.int64)
        for i in range(8)
    ]
    data = np.concatenate(parts)
    path = str(tmp_path / "mx.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<i8", 32, "zlib")
        f.write_chunked(meta, data)
        f.commit()
        assert {c.codec_id for c in meta.chunks} == {CODEC_NONE, CODEC_ZLIB}
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/d"), data)
        assert f.last_read_stats.n_chunks == 4  # only the zlib chunks decoded
        np.testing.assert_array_equal(f.read_rows("/d", 16, 64), data[16:80])


# -- sliding-window / LOD over compressed files --------------------------------


def test_lod_windows_over_compressed_dataset(tmp_path):
    from repro.core.sliding_window import iter_lod_windows, read_lod

    rng = np.random.default_rng(9)
    data = (rng.integers(0, 32, (256, 6)) / 32).astype(np.float32)
    path = str(tmp_path / "lod.th5")
    with TH5File.create(path) as f:
        meta = f.create_chunked_dataset("/d", data.shape, "<f4", 32, "zlib")
        f.write_chunked(meta, data)
        f.commit()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(read_lod(f, "/d", stride=4), data[::4])
        got = list(iter_lod_windows(f, "/d", [(0, 64), (32, 96), (200, 256)], max_rows=16))
        assert len(got) == 3 and all(len(g) <= 16 for g in got)
        # overlapping windows re-decode nothing: every chunk decoded once
        assert f.chunk_cache.stats()["misses"] <= 8


# -- checkpoint codec policy ---------------------------------------------------


def _mixed_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "fields": {"u": (rng.integers(0, 256, (256, 128)) / 256).astype(np.float32)},
        "opt": {
            "m": rng.random((256, 128)).astype(np.float32),
            "step": np.int64(11),  # tiny int leaf: must stay contiguous
        },
    }


def test_codec_policy_resolution():
    pol = CodecPolicy(default="zlib", rules=(("fields.*", "int8-blockq"),), min_chunk_bytes=64)
    assert pol.resolve("fields.u", np.zeros((64, 4), np.float32)) == "int8-blockq"
    # dtype heuristic: zlib on an f32/f64 leaf upgrades to the shuffle filter
    assert pol.resolve("opt.m", np.zeros((64, 4), np.float32)) == "shuffle+zlib"
    assert pol.resolve("opt.v", np.zeros((64, 4), np.float64)) == "shuffle+zlib"
    # ... but integer leaves keep plain zlib (shuffle buys little there)
    assert pol.resolve("opt.idx", np.zeros((64, 4), np.int32)) == "zlib"
    # lossy on an int leaf falls back to lossless (and stays unshuffled)
    assert pol.resolve("fields.mask", np.zeros((64, 4), np.int32)) == "zlib"
    # opting out of the heuristic restores plain zlib everywhere
    pol_plain = CodecPolicy(default="zlib", min_chunk_bytes=64, auto_shuffle=False)
    assert pol_plain.resolve("opt.m", np.zeros((64, 4), np.float32)) == "zlib"
    # the compression level rides through the upgrade
    pol6 = CodecPolicy(default="zlib:6", min_chunk_bytes=64)
    assert pol6.resolve("opt.m", np.zeros((64, 4), np.float32)) == "shuffle+zlib:6"
    # tiny / 0-d leaves stay on the contiguous zero-copy path
    assert pol.resolve("opt.step", np.int64(3)) == "none"
    assert pol.resolve("opt.m", np.zeros(4, np.float32)) == "none"
    assert CodecPolicy().resolve("anything", np.zeros((999, 9), np.float32)) == "none"
    assert pol.chunk_rows_for(10_000, 1 << 18) == 4  # ~1MiB target
    assert CodecPolicy(chunk_rows=64).chunk_rows_for(16, 8) == 16


def test_checkpoint_codec_policy_roundtrip(tmp_path):
    state = _mixed_state()
    pol = CodecPolicy(default="zlib", rules=(("fields.*", "int8-blockq"),), min_chunk_bytes=1024)
    with CheckpointManager(str(tmp_path / "c.th5")) as mgr:
        res = mgr.save(0, state, n_ranks=4, codec_policy=pol)
        assert res.filter_stats.n_chunks >= 2
        assert res.compression_ratio > 1.0
        assert mgr.latest_valid() == 0  # per-chunk CRC verification passes
        _, got = mgr.restore(0, verify=True)
        np.testing.assert_array_equal(got["opt"]["m"], state["opt"]["m"])  # lossless
        assert got["opt"]["step"] == state["opt"]["step"]
        u, u0 = got["fields"]["u"], state["fields"]["u"]
        assert np.abs(u.astype(np.float64) - u0).max() <= Int8BlockQCodec.tolerance(u0)
        # elastic restore reads a shard of a chunked leaf
        shard = mgr.restore_leaf_shard(0, "opt.m", rank=1, n_ranks=4)
        np.testing.assert_array_equal(shard, state["opt"]["m"][64:128])

    with CheckpointManager(str(tmp_path / "c.th5"), create=False) as mgr2:
        assert mgr2.latest_valid() == 0  # survives reopen (index round-trip)


def test_checkpoint_overwrite_invalidates_chunk_cache(tmp_path):
    with CheckpointManager(str(tmp_path / "o.th5")) as mgr:
        pol = CodecPolicy(default="zlib", min_chunk_bytes=64)
        a = {"w": np.full((64, 16), 1.0, np.float32)}
        b = {"w": np.full((64, 16), 2.0, np.float32)}
        mgr.save(0, a, codec_policy=pol)
        np.testing.assert_array_equal(mgr.restore(0)[1]["w"], a["w"])  # populates cache
        mgr.save(0, b, codec_policy=pol, overwrite=True)
        np.testing.assert_array_equal(mgr.restore(0)[1]["w"], b["w"])  # not stale


def test_save_without_policy_unchanged_zero_copy(tmp_path):
    """Default save (no codec policy) must keep the contiguous path and its
    stats shape — the PR-1 pipeline untouched."""
    with CheckpointManager(str(tmp_path / "n.th5")) as mgr:
        res = mgr.save(0, _mixed_state(), n_ranks=2)
        assert res.filter_stats.n_chunks == 0
        assert res.compression_ratio == 1.0
        for name in mgr.file.datasets():
            assert not mgr.file.meta(name).is_chunked
