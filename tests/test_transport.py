"""Wire-layer behaviour of the TH5 data service (``repro.service``
transport/wire/client).

The contract under test: framing survives arbitrary kernel chunking
(property-tested round-trips, torn streams raise instead of delivering
garbage), socket reads are BIT-IDENTICAL to direct ``TH5File`` reads,
backpressure crosses the wire as a typed BUSY carrying queue depth and
client id, service-side integrity errors still *name* the offending chunk
on the client, and QoS classes actually bite (a flooding bulk client
cannot starve an interactive one)."""

import os
import socket
import struct
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.aggregation import AggregationConfig, ChunkPipeline
from repro.core.checkpoint import CheckpointManager
from repro.core.container import CorruptFileError, TH5File
from repro.service import (
    AdmissionError,
    CatalogQuery,
    DataService,
    HyperslabQuery,
    PingQuery,
    RemoteDataService,
    ServiceConfig,
    ServiceServer,
    StatsQuery,
    SteeringRequest,
    SubscribeRequest,
    WindowQuery,
    WireDisconnect,
    WireError,
)
from repro.core.query import And, Cmp, Not, Or, QueryResult, col
from repro.service import QueryRequest
from repro.service import wire

from tests._hyp import given, settings, st

ROWS, COLS, CHUNK_ROWS = 512, 32, 64
DS_U = "/simulation/step_00000000/state/fields/u"
DS_FLAT = "/simulation/step_00000000/state/flat"


@pytest.fixture()
def run_file(tmp_path):
    rng = np.random.default_rng(7)
    u = rng.standard_normal((ROWS, COLS)).astype(np.float32)
    flat = rng.random((ROWS, COLS)).astype(np.float32)
    path = str(tmp_path / "run.th5")
    with TH5File.create(path) as f:
        mu = f.create_chunked_dataset(DS_U, u.shape, "<f4", CHUNK_ROWS, "shuffle+zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=2)) as pipe:
            pipe.write(mu, u)
        mf = f.create_dataset(DS_FLAT, flat.shape, "<f4")
        f.write_full(mf, flat, checksum=True)
        f.commit()
    return path, u, flat


@pytest.fixture()
def sock_dir():
    """Unix-socket paths must stay under ~100 bytes: use a short tempdir
    (pytest's tmp_path can blow the limit)."""
    with tempfile.TemporaryDirectory(prefix="th5w", dir="/tmp") as d:
        yield d


@pytest.fixture()
def served(run_file, sock_dir):
    """A DataService + ServiceServer on a Unix socket + one client."""
    path, u, flat = run_file
    with DataService(path, ServiceConfig(n_workers=2, max_queue=64)) as svc:
        with ServiceServer(svc, os.path.join(sock_dir, "svc.sock")) as server:
            with RemoteDataService(server.address) as remote:
                yield svc, server, remote, u, flat


# -- framing -------------------------------------------------------------------


def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        payload = np.arange(777, dtype="<i4")
        wire.send_frame(a, wire.KIND_OK, 42, {"x": [1, "two", None]}, payload)
        f = wire.recv_frame(b)
        assert (f.kind, f.req_id, f.meta) == (wire.KIND_OK, 42, {"x": [1, "two", None]})
        np.testing.assert_array_equal(np.frombuffer(f.payload, "<i4"), payload)
        # empty-meta, empty-payload frame
        wire.send_frame(a, wire.KIND_BUSY, 7, {})
        f2 = wire.recv_frame(b)
        assert (f2.kind, f2.req_id, f2.meta, len(f2.payload)) == (wire.KIND_BUSY, 7, {}, 0)
        a.close()
        assert wire.recv_frame(b) is None  # clean EOF between frames
    finally:
        for s in (a, b):
            s.close()


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(
        [
            wire.KIND_REQUEST,
            wire.KIND_OK,
            wire.KIND_ERROR,
            wire.KIND_SUBSCRIBE,
            wire.KIND_PUSH,
            wire.KIND_UNSUBSCRIBE,
        ]
    ),
    req_id=st.integers(min_value=0, max_value=2**63 - 1),
    meta=st.dictionaries(
        st.text(max_size=8),
        st.one_of(st.integers(-1000, 1000), st.text(max_size=16), st.booleans()),
        max_size=4,
    ),
    payload=st.binary(max_size=512),
)
def test_frame_roundtrip_property(kind, req_id, meta, payload):
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, kind, req_id, meta, payload)
        f = wire.recv_frame(b)
        assert (f.kind, f.req_id, f.meta, bytes(f.payload)) == (kind, req_id, meta, payload)
    finally:
        for s in (a, b):
            s.close()


def _pred_strategy(depth=2):
    leaf = st.builds(
        Cmp,
        column=st.integers(min_value=0, max_value=31),
        absolute=st.booleans(),
        op=st.sampled_from(["<", "<=", ">", ">=", "==", "!="]),
        value=st.floats(min_value=-1e6, max_value=1e6, allow_nan=False),
    )
    if depth == 0:
        return leaf
    sub = _pred_strategy(depth - 1)
    return st.one_of(
        leaf,
        st.builds(And, lhs=sub, rhs=sub),
        st.builds(Or, lhs=sub, rhs=sub),
        st.builds(Not, operand=sub),
    )


@settings(max_examples=40, deadline=None)
@given(
    req=st.one_of(
        st.builds(
            HyperslabQuery,
            dataset=st.text(min_size=1, max_size=20),
            row_start=st.integers(0, 10**6),
            n_rows=st.integers(0, 10**6),
            cols=st.one_of(st.none(), st.tuples(st.integers(0, 100), st.integers(0, 100))),
            verify=st.booleans(),
        ),
        st.builds(
            WindowQuery,
            dataset=st.text(min_size=1, max_size=20),
            rows=st.lists(st.integers(0, 2**40), max_size=64).map(tuple),
        ),
        st.builds(CatalogQuery, prefix=st.text(min_size=1, max_size=16)),
        st.builds(PingQuery, delay_s=st.floats(0, 1, allow_nan=False)),
        st.just(StatsQuery()),
        st.builds(
            SteeringRequest.branch,
            at_step=st.integers(0, 100),
            child_path=st.text(min_size=1, max_size=20),
            overlay=st.dictionaries(st.text(max_size=6), st.integers(-5, 5), max_size=3),
        ),
        st.builds(
            SubscribeRequest,
            dataset=st.text(min_size=1, max_size=20),
            rows=st.one_of(
                st.none(),
                st.tuples(st.integers(0, 10**6), st.integers(0, 10**6)).filter(
                    lambda t: t[0] < t[1]
                ),
            ),
            policy=st.sampled_from(["lossless", "drop-oldest"]),
            max_pending=st.integers(1, 10**6),
            from_chunk=st.integers(0, 2**40),
        ),
        st.builds(
            QueryRequest,
            dataset=st.text(min_size=1, max_size=20),
            predicate=_pred_strategy(),
            row_start=st.integers(0, 10**6),
            n_rows=st.one_of(st.none(), st.integers(0, 10**6)),
            verify=st.booleans(),
        ),
    )
)
def test_request_codec_roundtrip_property(req):
    meta, payload = wire.encode_request("cli-π", req)
    # the meta blob must be JSON-serializable exactly as send_frame does it
    import json

    meta = json.loads(json.dumps(meta))
    client, back = wire.decode_request(
        meta, memoryview(payload.tobytes() if payload is not None else b"")
    )
    assert client == "cli-π"
    assert back == req


@settings(max_examples=30, deadline=None)
@given(
    dtype=st.sampled_from(["<f4", "<f8", "<i2", "<i8", "|u1"]),
    shape=st.one_of(
        st.tuples(st.integers(0, 40)),
        st.tuples(st.integers(0, 12), st.integers(1, 12)),
        st.tuples(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5)),
    ),
)
def test_value_codec_ndarray_roundtrip_property(dtype, shape):
    rng = np.random.default_rng(3)
    arr = (rng.random(shape) * 100).astype(dtype)
    desc, payload = wire.encode_value(arr)
    back = wire.decode_value(desc, memoryview(bytearray(payload.tobytes())))
    assert back.dtype == arr.dtype and back.shape == arr.shape
    np.testing.assert_array_equal(back, arr)
    assert back.flags.writeable  # recv buffers become writable client arrays


class _TrickleSock:
    """recv_into wrapper returning at most ``n`` bytes per call — the
    kernel is allowed to chunk a stream arbitrarily; the framing layer
    must not care."""

    def __init__(self, sock, n=3):
        self._sock, self._n = sock, n

    def recv_into(self, view):
        return self._sock.recv_into(view[: self._n])


def test_recv_resumes_across_torn_reads():
    a, b = socket.socketpair()
    try:
        payload = np.arange(199, dtype="<u2")
        meta = {"k": "v" * 50}
        wire.send_frame(a, wire.KIND_OK, 9, meta, payload)
        f = wire.recv_frame(_TrickleSock(b))
        assert f.meta == meta and f.req_id == 9
        np.testing.assert_array_equal(np.frombuffer(f.payload, "<u2"), payload)
    finally:
        for s in (a, b):
            s.close()


def test_midframe_disconnect_raises_not_garbage():
    # partial header
    a, b = socket.socketpair()
    a.sendall(b"TH5W\x01")
    a.close()
    with pytest.raises(WireDisconnect, match="mid-frame"):
        wire.recv_frame(b)
    b.close()
    # full header promising a payload that never arrives
    a, b = socket.socketpair()
    hdr = struct.pack(wire.HEADER_FMT, wire.MAGIC, wire.WIRE_VERSION, wire.KIND_OK, 0, 1, 2, 100)
    a.sendall(hdr + b"{}")
    a.close()
    with pytest.raises(WireDisconnect):
        wire.recv_frame(b)
    b.close()


def _captured_frame_bytes() -> bytes:
    """The exact on-wire bytes of one representative OK frame (header +
    meta + payload), captured from ``send_frame`` itself so the torn-stream
    tests cut real encoder output, not a hand-rolled imitation."""
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, wire.KIND_OK, 11, {"k": "v" * 40}, np.arange(64, dtype="<u4"))
        a.close()
        blob = b""
        while True:
            part = b.recv(1 << 16)
            if not part:
                return blob
            blob += part
    finally:
        b.close()


_FRAME_BYTES = _captured_frame_bytes()


@settings(max_examples=60, deadline=None)
@given(cut=st.integers(min_value=1, max_value=len(_FRAME_BYTES) - 1))
def test_torn_stream_any_cut_point_raises_wiredisconnect(cut):
    """A peer dying at ANY byte of a frame — mid-header, mid-meta or
    mid-payload — must surface as WireDisconnect, never as garbage data
    and never as a clean EOF."""
    a, b = socket.socketpair()
    try:
        a.sendall(_FRAME_BYTES[:cut])
        a.close()
        with pytest.raises(WireDisconnect):
            wire.recv_frame(b)
    finally:
        b.close()


def test_torn_stream_boundary_cuts():
    """Deterministic anchors for the property above: last header byte,
    first meta byte, mid-meta, and last payload byte."""
    hdr, meta_len = wire.HEADER_SIZE, len(b'{"k": "' + b"v" * 40 + b'"}')
    for cut in (1, hdr - 1, hdr, hdr + 1, hdr + meta_len // 2, len(_FRAME_BYTES) - 1):
        a, b = socket.socketpair()
        try:
            a.sendall(_FRAME_BYTES[:cut])
            a.close()
            with pytest.raises(WireDisconnect):
                wire.recv_frame(b)
        finally:
            b.close()


# -- trace-context propagation (repro.obs stitching rides frame meta) ----------


def test_trace_meta_roundtrips_through_request_frame():
    """A traced request's (trace_id, parent_span_id) pair must survive the
    full encode → frame → decode path: `decode_request` still yields the
    exact request (unknown meta keys ignored), and `get_trace` recovers
    the context on the server side."""
    req = WindowQuery(dataset=DS_U, rows=(3, 5, 8))
    meta, payload = wire.encode_request("viewer", req)
    wire.put_trace(meta, 0xBEEF_CAFE, 41)
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, wire.KIND_REQUEST, 9, meta, payload)
        f = wire.recv_frame(b)
    finally:
        for s in (a, b):
            s.close()
    client, back = wire.decode_request(f.meta, f.payload)
    assert (client, back) == ("viewer", req)
    ctx = wire.get_trace(f.meta)
    assert (ctx.trace_id, ctx.span_id) == (0xBEEF_CAFE, 41)


@settings(max_examples=30, deadline=None)
@given(
    trace_id=st.integers(min_value=1, max_value=2**63 - 1),
    span_id=st.integers(min_value=0, max_value=2**31 - 1),
    req_id=st.integers(min_value=0, max_value=2**63 - 1),
)
def test_trace_meta_roundtrip_property(trace_id, span_id, req_id):
    meta, payload = wire.encode_request("cli", HyperslabQuery(DS_U, 0, 4))
    wire.put_trace(meta, trace_id, span_id)
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, wire.KIND_REQUEST, req_id, meta, payload)
        f = wire.recv_frame(b)
    finally:
        for s in (a, b):
            s.close()
    ctx = wire.get_trace(f.meta)
    assert (ctx.trace_id, ctx.span_id) == (trace_id, span_id)
    assert f.req_id == req_id


def _captured_traced_request_bytes() -> bytes:
    """On-wire bytes of a REQUEST frame carrying trace meta, from the real
    encoder — the torn-stream property below cuts THESE bytes."""
    meta, payload = wire.encode_request(
        "viewer", WindowQuery(dataset=DS_U, rows=tuple(range(16)))
    )
    wire.put_trace(meta, 0x1234_5678_9ABC, 17)
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, wire.KIND_REQUEST, 5, meta, payload)
        a.close()
        blob = b""
        while True:
            part = b.recv(1 << 16)
            if not part:
                return blob
            blob += part
    finally:
        b.close()


_TRACED_FRAME_BYTES = _captured_traced_request_bytes()


@settings(max_examples=40, deadline=None)
@given(cut=st.integers(min_value=1, max_value=len(_TRACED_FRAME_BYTES) - 1))
def test_torn_traced_request_any_cut_raises_wiredisconnect(cut):
    """Trace meta fattens the JSON blob but must not change torn-stream
    semantics: a peer dying at any byte of a traced REQUEST still raises
    WireDisconnect, never yields garbage or a clean EOF."""
    a, b = socket.socketpair()
    try:
        a.sendall(_TRACED_FRAME_BYTES[:cut])
        a.close()
        with pytest.raises(WireDisconnect):
            wire.recv_frame(b)
    finally:
        b.close()


def test_intact_traced_request_decodes_after_torn_attempts():
    """The whole traced frame, delivered intact, round-trips: the trace
    pair and the request both come back exact."""
    a, b = socket.socketpair()
    try:
        a.sendall(_TRACED_FRAME_BYTES)
        a.close()
        f = wire.recv_frame(b)
    finally:
        b.close()
    client, req = wire.decode_request(f.meta, f.payload)
    assert client == "viewer" and req.rows == tuple(range(16))
    assert wire.get_trace(f.meta) == (0x1234_5678_9ABC, 17)


def _captured_push_frame_bytes() -> bytes:
    """The exact on-wire bytes of one representative KIND_PUSH frame, as
    the transport's subscription sink builds it: push metadata + an
    ndarray value descriptor + the chunk rows as payload."""
    a, b = socket.socketpair()
    try:
        rows = np.arange(64 * 8, dtype="<f4").reshape(64, 8)
        desc, payload = wire.encode_value(rows)
        meta = {
            "dataset": "/simulation/step_00000000/state/fields/u",
            "chunk_index": 3, "row_start": 192, "n_rows": 64,
            "generation": 5, "seq": 2, "dropped": 0, "value": desc,
        }
        wire.send_frame(a, wire.KIND_PUSH, 17, meta, payload)
        a.close()
        blob = b""
        while True:
            part = b.recv(1 << 16)
            if not part:
                return blob
            blob += part
    finally:
        b.close()


_PUSH_FRAME_BYTES = _captured_push_frame_bytes()


def test_push_frame_roundtrip_bit_identical():
    a, b = socket.socketpair()
    try:
        rows = np.arange(64 * 8, dtype="<f4").reshape(64, 8)
        desc, payload = wire.encode_value(rows)
        meta = {"dataset": "/u", "chunk_index": 3, "row_start": 192, "value": desc}
        wire.send_frame(a, wire.KIND_PUSH, 17, meta, payload)
        f = wire.recv_frame(b)
        assert (f.kind, f.req_id) == (wire.KIND_PUSH, 17)
        assert f.meta["chunk_index"] == 3 and f.meta["row_start"] == 192
        np.testing.assert_array_equal(wire.decode_value(f.meta["value"], f.payload), rows)
    finally:
        for s in (a, b):
            s.close()


@settings(max_examples=60, deadline=None)
@given(cut=st.integers(min_value=1, max_value=len(_PUSH_FRAME_BYTES) - 1))
def test_torn_push_stream_any_cut_point_raises_wiredisconnect(cut):
    """A subscription's connection dying at ANY byte of a PUSH frame must
    surface as WireDisconnect — the client's reconnect path then
    re-subscribes from its cursor; a torn push must never decode as a
    short or corrupt chunk."""
    a, b = socket.socketpair()
    try:
        a.sendall(_PUSH_FRAME_BYTES[:cut])
        a.close()
        with pytest.raises(WireDisconnect):
            wire.recv_frame(b)
    finally:
        b.close()


def test_subscribe_codec_defaults_fill_missing_fields():
    """A decoder seeing a minimal SUBSCRIBE meta (older/terse client) fills
    policy, max_pending and from_chunk with the documented defaults."""
    meta, payload = wire.encode_request("v", SubscribeRequest(dataset="/u"))
    for absent in ("policy", "max_pending", "from_chunk"):
        meta.pop(absent)
    client, back = wire.decode_request(meta, memoryview(b""))
    assert client == "v"
    assert back == SubscribeRequest(dataset="/u")
    assert (back.policy, back.max_pending, back.from_chunk) == ("lossless", 64, 0)


# -- predicate-pushdown query frames -------------------------------------------


def test_query_request_codec_nan_and_inf_constants():
    """NaN / ±inf predicate constants survive the wire (the meta JSON path
    must not mangle them) — compared field-wise since NaN != NaN.  The
    encoded meta must also be strict RFC 8259 JSON: non-finite constants
    ride as string sentinels, never NaN/Infinity tokens."""
    import json
    import math

    for const in (float("nan"), float("inf"), float("-inf")):
        req = QueryRequest("/d", col(2) != const, row_start=7, n_rows=None)
        meta, payload = wire.encode_request("q", req)
        strict = json.dumps(meta, allow_nan=False)  # raises on a token leak
        client, back = wire.decode_request(json.loads(strict), memoryview(b""))
        assert client == "q" and isinstance(back, QueryRequest)
        assert (back.dataset, back.row_start, back.n_rows) == ("/d", 7, None)
        assert back.predicate.op == "!=" and back.predicate.column == 2
        if math.isnan(const):
            assert math.isnan(back.predicate.value)
        else:
            assert back.predicate.value == const


def test_query_request_codec_rejects_malformed_predicate():
    req = QueryRequest("/d", col(0) > 1.0)
    meta, _ = wire.encode_request("q", req)
    meta["predicate"] = ["bogus-op", 0, 0, ">", 1.0]
    with pytest.raises(WireError, match="predicate"):
        wire.decode_request(meta, memoryview(b""))


def _make_query_result(n=96, dtype="<f4", seed=5):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.3
    window = rng.normal(size=(n, 4)).astype(dtype)
    return QueryResult(
        rows=np.ascontiguousarray(window[mask]),
        index=10 + np.flatnonzero(mask).astype(np.int64),
        mask=mask,
        row_start=10,
        n_chunks=6,
        chunks_pruned=4,
        chunks_decoded=2,
        invalid_stats=(1, 3),
    )


def test_query_value_codec_roundtrip_bit_identical():
    res = _make_query_result()
    desc, payload = wire.encode_value(res)  # payload is raw bytes for queries
    back = wire.decode_value(desc, memoryview(bytearray(payload)))
    assert isinstance(back, QueryResult)
    assert back.rows.tobytes() == res.rows.tobytes()
    assert back.rows.dtype == res.rows.dtype and back.rows.shape == res.rows.shape
    np.testing.assert_array_equal(back.mask, res.mask)
    np.testing.assert_array_equal(back.index, res.index)
    assert (back.row_start, back.n_chunks, back.chunks_pruned, back.chunks_decoded) == (10, 6, 4, 2)
    assert back.invalid_stats == (1, 3)
    assert back.pruned_ratio == res.pruned_ratio


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=200),
    dtype=st.sampled_from(["<f4", "<f8", "<i4"]),
    seed=st.integers(0, 9),
)
def test_query_value_codec_roundtrip_property(n, dtype, seed):
    """Mask bit-packing round-trips for every window length, including
    lengths not divisible by 8 and the empty window."""
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.5
    window = (rng.normal(size=(n, 3)) * 50).astype(dtype)
    res = QueryResult(
        rows=np.ascontiguousarray(window[mask]),
        index=np.flatnonzero(mask).astype(np.int64),
        mask=mask, row_start=0, n_chunks=0, chunks_pruned=0, chunks_decoded=0,
    )
    desc, payload = wire.encode_value(res)
    back = wire.decode_value(desc, memoryview(bytearray(payload)))
    assert back.mask.shape == (n,)
    np.testing.assert_array_equal(back.mask, mask)
    assert back.rows.tobytes() == res.rows.tobytes()
    np.testing.assert_array_equal(back.index, res.index)


def _captured_query_frame_bytes() -> bytes:
    """On-wire bytes of one OK frame carrying a query result, captured from
    the real encoder for the torn-stream cuts below."""
    a, b = socket.socketpair()
    try:
        desc, payload = wire.encode_value(_make_query_result())
        wire.send_frame(a, wire.KIND_OK, 23, {"value": desc}, payload)
        a.close()
        blob = b""
        while True:
            part = b.recv(1 << 16)
            if not part:
                return blob
            blob += part
    finally:
        b.close()


_QUERY_FRAME_BYTES = _captured_query_frame_bytes()


def test_query_frame_roundtrip_over_socket():
    a, b = socket.socketpair()
    try:
        a.sendall(_QUERY_FRAME_BYTES)
        a.close()
        f = wire.recv_frame(b)
        back = wire.decode_value(f.meta["value"], f.payload)
        want = _make_query_result()
        assert back.rows.tobytes() == want.rows.tobytes()
        np.testing.assert_array_equal(back.mask, want.mask)
    finally:
        b.close()


@settings(max_examples=60, deadline=None)
@given(cut=st.integers(min_value=1, max_value=len(_QUERY_FRAME_BYTES) - 1))
def test_torn_query_stream_any_cut_point_raises_wiredisconnect(cut):
    """A peer dying at any byte of a query-result frame — mid-rows or
    mid-packed-mask — must surface as WireDisconnect, never as a short
    mask or truncated row block."""
    a, b = socket.socketpair()
    try:
        a.sendall(_QUERY_FRAME_BYTES[:cut])
        a.close()
        with pytest.raises(WireDisconnect):
            wire.recv_frame(b)
    finally:
        b.close()


def test_torn_query_stream_boundary_cuts():
    """Deterministic anchors: mid-header, header end, end of rows bytes
    (start of the packed mask), and last byte."""
    want = _make_query_result()
    rows_end = len(_QUERY_FRAME_BYTES) - (len(want.mask) + 7) // 8
    for cut in (1, wire.HEADER_SIZE, rows_end, len(_QUERY_FRAME_BYTES) - 1):
        a, b = socket.socketpair()
        try:
            a.sendall(_QUERY_FRAME_BYTES[:cut])
            a.close()
            with pytest.raises(WireDisconnect):
                wire.recv_frame(b)
        finally:
            b.close()


def test_bad_magic_and_oversized_frames_rejected():
    a, b = socket.socketpair()
    try:
        a.sendall(b"X" * wire.HEADER_SIZE)
        with pytest.raises(WireError, match="magic"):
            wire.recv_frame(b)
    finally:
        a.close()
        b.close()
    a, b = socket.socketpair()
    try:
        hdr = struct.pack(
            wire.HEADER_FMT, wire.MAGIC, wire.WIRE_VERSION, wire.KIND_OK, 0, 1,
            wire.MAX_META_BYTES + 1, 0,
        )
        a.sendall(hdr)
        with pytest.raises(WireError, match="too large"):
            wire.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_gated_ping_refuses_the_wire():
    with pytest.raises(TypeError, match="gated PingQuery"):
        wire.encode_request("c", PingQuery(gate=threading.Event()))


# -- socket reads vs direct reads ----------------------------------------------


def test_socket_reads_bit_identical_to_direct(served):
    svc, server, remote, u, flat = served
    path = svc.path
    with TH5File.open(path) as direct:
        for req in [
            HyperslabQuery(DS_U, 0, ROWS),
            HyperslabQuery(DS_U, 37, 200, cols=(3, 19)),
            HyperslabQuery(DS_FLAT, 100, 50, verify=True),
            HyperslabQuery(DS_U, 64, 128, verify=True),
        ]:
            got = remote.request("cli", req).value
            want = direct.read_rows(req.dataset, req.row_start, req.n_rows)
            if req.cols:
                want = want[:, req.cols[0] : req.cols[1]]
            np.testing.assert_array_equal(got, want)
            assert got.dtype == want.dtype
        rows = [5, 1, 63, 64, 65, 200, 511, 2, 2]
        got = remote.request("cli", WindowQuery(DS_U, tuple(rows))).value
        np.testing.assert_array_equal(got, direct.read_row_indices(DS_U, rows))


def test_concurrent_remote_clients_bit_identical(served):
    svc, server, remote, u, flat = served
    rng = np.random.default_rng(11)
    scripts = []
    for c in range(4):
        script = []
        for _ in range(8):
            if rng.integers(2):
                lo = int(rng.integers(0, ROWS - 64))
                n = min(int(rng.integers(1, 128)), ROWS - lo)
                script.append(HyperslabQuery(DS_U if rng.integers(2) else DS_FLAT, lo, n))
            else:
                rows = tuple(int(r) for r in rng.choice(ROWS, size=48, replace=False))
                script.append(WindowQuery(DS_U, rows))
        scripts.append(script)

    def expected(req):
        src = u if req.dataset == DS_U else flat
        if isinstance(req, HyperslabQuery):
            return src[req.row_start : req.row_start + req.n_rows]
        return src[list(req.rows)]

    def play(c):
        futs = [(remote.submit(f"c{c}", r), r) for r in scripts[c]]
        for fut, req in futs:  # pipelined: all in flight before first result
            np.testing.assert_array_equal(fut.result(timeout=60).value, expected(req))

    with ThreadPoolExecutor(max_workers=4) as pool:
        for f in [pool.submit(play, c) for c in range(4)]:
            f.result()
    st_ = remote.stats()
    assert st_.completed >= 4 * 8 and st_.failed == 0


def test_window_session_over_socket_matches_direct(served):
    """LodWindowSession runs UNMODIFIED against the remote client."""
    svc, server, remote, u, _ = served
    windows = [(lo, lo + 128) for lo in range(0, ROWS - 128 + 1, 64)]
    with TH5File.open(svc.path) as direct:
        want = [direct.read_row_indices(DS_U, list(range(lo, hi, 2))) for lo, hi in windows]
    ses = remote.open_window_session("viewer", DS_U, windows, max_rows=64)
    got = list(ses)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_tcp_transport_and_ephemeral_port(run_file):
    path, u, _ = run_file
    with DataService(path, ServiceConfig(n_workers=2)) as svc:
        with ServiceServer(svc, ("127.0.0.1", 0)) as server:
            host, port = server.address
            assert port != 0
            with RemoteDataService((host, port)) as remote:
                got = remote.request("t", HyperslabQuery(DS_U, 10, 30)).value
                np.testing.assert_array_equal(got, u[10:40])


def test_remote_catalog_and_steering(tmp_path, sock_dir):
    root = str(tmp_path / "root.th5")
    with CheckpointManager(root, common={"nu": 0.01}) as mgr:
        for s in (10, 20):
            mgr.save(s, {"T": np.full((64, 4), float(s), np.float32)})
    with DataService(root) as svc, ServiceServer(svc, os.path.join(sock_dir, "s.sock")) as server:
        with RemoteDataService(server.address) as remote:
            cat = remote.request("b", CatalogQuery()).value
            assert cat.steps == (10, 20)
            assert cat.leaves_by_step[20] == ("T",)
            assert all(d.nbytes > 0 for d in cat.datasets)
            child = str(tmp_path / "child.th5")
            res = remote.request("b", SteeringRequest.branch(10, child, {"nu": 0.02})).value
            assert res.op == "branch" and res.child_path == child
            assert res.steps == (10,)
            assert res.lineage[-1] == (child, 10)


# -- backpressure & errors over the wire ---------------------------------------


def test_remote_busy_carries_queue_depth_and_client(run_file, sock_dir):
    path, _, _ = run_file
    cfg = ServiceConfig(n_workers=1, max_queue=1)
    with DataService(path, cfg) as svc, ServiceServer(svc, os.path.join(sock_dir, "s.sock")) as server:
        with RemoteDataService(server.address) as remote:
            # occupy the single worker, then fill the 1-deep queue
            blocker = remote.submit("greedy", PingQuery(delay_s=3.0))
            deadline = time.time() + 30
            while svc.stats().inflight == 0:  # blocker picked up
                assert time.time() < deadline
                time.sleep(0.005)
            futs = [remote.submit("greedy", PingQuery()) for _ in range(6)]
            rejected = []
            for f in futs:
                try:
                    f.result(timeout=60)
                except AdmissionError as e:
                    rejected.append(e)
            assert rejected, "expected at least one wire BUSY"
            assert all(e.client == "greedy" for e in rejected)
            assert all(e.queue_depth >= 1 for e in rejected)
            assert "queue full" in str(rejected[0])
            blocker.result(timeout=60)
            # service recovered: new remote requests still answered
            assert remote.request("greedy", PingQuery()).value is None
            assert remote.stats().rejected >= len(rejected)


def test_remote_error_names_offending_chunk(run_file, sock_dir):
    path, u, _ = run_file
    with TH5File.open(path) as f:
        rec = f.meta(DS_U).chunks[2]
    with open(path, "r+b") as fh:  # flip bytes inside chunk 2's stored extent
        fh.seek(rec.offset + rec.nbytes // 2)
        fh.write(b"\xde\xad\xbe\xef")
    with DataService(path) as svc, ServiceServer(svc, os.path.join(sock_dir, "s.sock")) as server:
        with RemoteDataService(server.address) as remote:
            fut = remote.submit("v", HyperslabQuery(DS_U, 0, ROWS, verify=True))
            with pytest.raises(CorruptFileError, match=rf"chunk 2 of {DS_U}"):
                fut.result(timeout=60)
            # unverified read of an untouched chunk still serves
            got = remote.request("v", HyperslabQuery(DS_U, 0, CHUNK_ROWS)).value
            np.testing.assert_array_equal(got, u[:CHUNK_ROWS])


def test_client_close_fails_pending_and_server_survives(served):
    svc, server, remote, u, _ = served
    with RemoteDataService(server.address) as extra:
        fut = extra.submit("doomed", PingQuery(delay_s=1.0))
        extra.close()
        with pytest.raises(Exception):
            fut.result(timeout=60)
    # the server and other connections keep working
    got = remote.request("ok", HyperslabQuery(DS_U, 0, 8)).value
    np.testing.assert_array_equal(got, u[:8])


def test_hello_rejects_unknown_qos_class(served):
    svc, server, remote, u, _ = served
    bad = RemoteDataService(server.address, qos="platinum")
    try:
        with pytest.raises(Exception, match="platinum|closed"):
            bad.request("x", PingQuery())
    finally:
        bad.close()


def test_stalled_consumer_evicted_not_wedging_workers(run_file, sock_dir):
    """Slow-consumer eviction: a peer that submits a large read and never
    drains its socket is disconnected after the send timeout — it cannot
    wedge the worker pool, and healthy clients keep being served."""
    path, u, _ = run_file
    addr = os.path.join(sock_dir, "s.sock")
    cfg = ServiceConfig(n_workers=2, max_queue=64)
    with DataService(path, cfg) as svc:
        with ServiceServer(svc, addr, sock_buf_bytes=1 << 14, send_timeout_s=1.0) as server:
            # raw stalling peer: HELLO + a ~1 MB window gather, then never recv
            stall = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            stall.connect(addr)
            stall.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 1 << 12)
            try:
                wire.send_frame(stall, wire.KIND_HELLO, 0, {"version": wire.WIRE_VERSION})
                big = tuple(range(ROWS)) * 16  # 8192 rows × 128 B = ~1 MB reply
                meta, payload = wire.encode_request("staller", WindowQuery(DS_U, big))
                wire.send_frame(stall, wire.KIND_REQUEST, 1, meta, payload)
                with RemoteDataService(server.address) as healthy:
                    deadline = time.time() + 30
                    # the healthy client is served the whole time...
                    while server.n_connections > 1:
                        got = healthy.request("ok", HyperslabQuery(DS_U, 0, 8)).value
                        np.testing.assert_array_equal(got, u[:8])
                        assert time.time() < deadline, "stalled peer never evicted"
                        time.sleep(0.05)
                    # ...and the staller's connection is gone
                    np.testing.assert_array_equal(
                        healthy.request("ok", HyperslabQuery(DS_U, 8, 8)).value, u[8:16]
                    )
            finally:
                stall.close()


# -- QoS over the wire ---------------------------------------------------------


def test_hello_qos_class_lands_in_stats(run_file, sock_dir):
    path, _, _ = run_file
    with DataService(path) as svc, ServiceServer(svc, os.path.join(sock_dir, "s.sock")) as server:
        with RemoteDataService(server.address, qos="bulk") as bulk_conn:
            with RemoteDataService(server.address) as inter_conn:
                bulk_conn.request("replayer", PingQuery())
                inter_conn.request("viewer", PingQuery())
                st_ = inter_conn.stats()
    assert st_.clients["replayer"].qos_class == "bulk"
    assert st_.clients["viewer"].qos_class == "interactive"
    assert st_.qos["bulk"]["clients"] == 1
    assert st_.qos["interactive"]["clients"] == 1
    assert st_.qos["interactive"]["weight"] > st_.qos["bulk"]["weight"]


# -- accept/HELLO hardening ----------------------------------------------------


def test_garbage_and_midhello_death_do_not_kill_listener(run_file, sock_dir):
    """Hostile or dying peers before HELLO: pure garbage, a connection cut
    mid-HELLO frame, and a silent connect-then-vanish.  Each is closed and
    counted without taking down the listener, leaking a connection, or
    leaking threads."""
    path, u, _ = run_file
    addr = os.path.join(sock_dir, "s.sock")
    with DataService(path) as svc, ServiceServer(svc, addr) as server:
        n_threads = threading.active_count()

        def raw_conn():
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.connect(addr)
            return s

        g = raw_conn()  # garbage where the HELLO frame should be
        g.sendall(b"\x00not-a-frame\xff" * 8)
        g.close()
        h = raw_conn()  # death mid-HELLO (partial frame header)
        h.sendall(wire.MAGIC + b"\x01")
        h.close()
        v = raw_conn()  # connect and vanish without a byte
        v.close()

        deadline = time.time() + 30
        while server.stats()["hello_failures"] < 2 or server.n_connections > 0:
            assert time.time() < deadline, f"stats never settled: {server.stats()}"
            time.sleep(0.01)
        # the listener still serves real clients afterwards
        with RemoteDataService(server.address) as ok:
            got = ok.request("ok", HyperslabQuery(DS_U, 0, 8)).value
            np.testing.assert_array_equal(got, u[:8])
        st_ = server.stats()
        assert st_["accepted"] >= 4 and st_["active"] == 0 and st_["inflight"] == 0
        # the doomed connections' reader/sender threads are gone too
        deadline = time.time() + 30
        while threading.active_count() > n_threads:
            assert time.time() < deadline, "leaked connection threads"
            time.sleep(0.01)
