"""Checkpoint manager: snapshot roundtrip, topology, elasticity, async, resume."""

import os

import numpy as np
import pytest

from repro.core import uid
from repro.core.checkpoint import AsyncCheckpointer, CheckpointManager, split_rows
from repro.core.container import TH5File


def make_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "embed": rng.standard_normal((64, 16)).astype(np.float32),
            "layers": [
                {"w": rng.standard_normal((16, 16)).astype(np.float32), "b": np.zeros(16, np.float32)}
                for _ in range(3)
            ],
        },
        "opt": {"mu": rng.standard_normal((64, 16)).astype(np.float32), "count": np.int64(7)},
        "step": 42,
        "rng_key": np.array([1, 2], dtype=np.uint32),
        "none_field": None,
        "tuple_field": (np.float32(0.5), np.arange(4)),
    }


def assert_state_equal(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            assert_state_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert type(a) is type(b) and len(a) == len(b)
        for x, y in zip(a, b):
            assert_state_equal(x, y)
    elif a is None:
        assert b is None
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_restore_roundtrip(tmp_path):
    p = str(tmp_path / "run.th5")
    state = make_state()
    with CheckpointManager(p, common={"model": "tiny"}) as mgr:
        res = mgr.save(100, state, n_ranks=4)
        assert res.bytes_data > 0
        step, got = mgr.restore()
        assert step == 100
        assert_state_equal(got, state)
        assert mgr.common()["model"] == "tiny"


def test_multiple_steps_append(tmp_path):
    p = str(tmp_path / "run.th5")
    with CheckpointManager(p) as mgr:
        for s in (10, 20, 30):
            mgr.save(s, {"x": np.full(8, s, np.float32)})
        assert mgr.steps() == [10, 20, 30]
        _, st20 = mgr.restore(20)
        np.testing.assert_array_equal(st20["x"], np.full(8, 20, np.float32))
    # reopen (resume path)
    with CheckpointManager(p, create=False) as mgr:
        assert mgr.latest_step() == 30


def test_nranks_independent_of_restore(tmp_path):
    """Write with 8 ranks, read whole; paper: restart on any process count."""
    p = str(tmp_path / "run.th5")
    state = make_state(3)
    with CheckpointManager(p) as mgr:
        mgr.save(1, state, n_ranks=8)
        _, got = mgr.restore(1)
        assert_state_equal(got, state)


def test_elastic_leaf_shard_restore(tmp_path):
    """Save under 8 ranks, restore shards under 3 ranks, reassemble."""
    p = str(tmp_path / "run.th5")
    x = np.arange(13 * 5, dtype=np.float32).reshape(13, 5)
    with CheckpointManager(p) as mgr:
        mgr.save(1, {"x": x}, n_ranks=8)
        parts = [mgr.restore_leaf_shard(1, "x", r, 3) for r in range(3)]
        np.testing.assert_array_equal(np.concatenate(parts), x)
        counts = [p_.shape[0] for p_ in parts]
        np.testing.assert_array_equal(counts, split_rows(13, 3))


def test_topology_datasets(tmp_path):
    """grid_property: rank-ordered UIDs, root chunk at row 0 (paper Fig. 4)."""
    p = str(tmp_path / "run.th5")
    with CheckpointManager(p) as mgr:
        mgr.save(5, {"a": np.zeros((16, 2), np.float32), "b": np.ones((4,), np.float32)}, n_ranks=2)
        uids, boxes, order = mgr.topology(5)
        ranks, locals_, _, _ = uid.unpack_array(uids)
        # rank-major ordering
        assert (np.diff(ranks.astype(np.int64)) >= 0).all()
        assert ranks[0] == 0 and locals_[0] == 0  # root chunk at row 0
        assert boxes.shape[1] == 3
        assert order == sorted(order)


def test_checksum_detects_corruption_and_fallback(tmp_path):
    """Bit-rot in newest snapshot → latest_valid falls back one step."""
    p = str(tmp_path / "run.th5")
    with CheckpointManager(p) as mgr:
        mgr.save(1, {"x": np.zeros(1024, np.float32)})
        mgr.save(2, {"x": np.ones(1024, np.float32)})
        meta = mgr.file.meta("/simulation/step_00000002/state/x")
        off = meta.offset
    with open(p, "r+b") as fh:
        fh.seek(off + 17)
        fh.write(b"\x55")
    with CheckpointManager(p, create=False) as mgr:
        assert mgr.latest_valid() == 1
        step, st = mgr.restore()  # auto-fallback
        assert step == 1
        np.testing.assert_array_equal(st["x"], np.zeros(1024, np.float32))


def test_torn_write_invisible(tmp_path):
    """Kill mid-save (before commit): reopened file shows only prior steps."""
    p = str(tmp_path / "run.th5")
    mgr = CheckpointManager(p)
    mgr.save(1, {"x": np.zeros(8, np.float32)})
    # simulate a crash inside save: write slabs manually without commit
    f = mgr.file
    d = f.create_dataset("/simulation/step_00000002/state/x", (8,), "<f4")
    f.write_full(d, np.ones(8, np.float32))
    os.close(f.fd)  # no commit — process died
    f._closed = True
    with CheckpointManager(p, create=False) as mgr2:
        assert mgr2.steps() == [1]
        assert mgr2.latest_valid() == 1


def test_async_checkpointer_overlap(tmp_path):
    p = str(tmp_path / "run.th5")
    with CheckpointManager(p) as mgr:
        ac = AsyncCheckpointer(mgr)
        state = {"x": np.arange(32, dtype=np.float32)}
        ac.save(1, state)
        state["x"][:] = -1  # mutate after save returns — staging must have copied
        res = ac.wait()
        assert res is not None and res.step == 1
        _, got = mgr.restore(1)
        np.testing.assert_array_equal(got["x"], np.arange(32, dtype=np.float32))


def test_async_error_surfaces(tmp_path):
    p = str(tmp_path / "run.th5")
    with CheckpointManager(p) as mgr:
        ac = AsyncCheckpointer(mgr)
        ac.save(1, {"x": np.zeros(4, np.float32)})
        ac.wait()
        ac.save(1, {"x": np.zeros(4, np.float32)})  # duplicate step → error
        with pytest.raises(ValueError):
            ac.wait()


def test_duplicate_step_rejected(tmp_path):
    p = str(tmp_path / "run.th5")
    with CheckpointManager(p) as mgr:
        mgr.save(1, {"x": np.zeros(4, np.float32)})
        with pytest.raises(ValueError):
            mgr.save(1, {"x": np.zeros(4, np.float32)})


def test_split_rows_balanced():
    np.testing.assert_array_equal(split_rows(10, 3), [4, 3, 3])
    np.testing.assert_array_equal(split_rows(2, 4), [1, 1, 0, 0])
    assert split_rows(0, 4).sum() == 0


def test_codec_policy_default_table(tmp_path):
    """CodecPolicy.default() (ROADMAP open item, first slice): the measured
    per-dtype / per-leaf-name table resolves fields to the lossy codec,
    large float leaves to shuffle+zlib, integers to plain zlib, and small
    leaves to the contiguous zero-copy path — and attaching it at manager
    construction means save() needs no per-call policy."""
    from repro.core.checkpoint import CodecPolicy

    pol = CodecPolicy.default()
    big_f32 = np.zeros((4096, 64), np.float32)
    assert pol.resolve("fields/u", big_f32) == "int8-blockq"
    assert pol.resolve("sim/fields/p", big_f32) == "int8-blockq"
    assert pol.resolve("params/w", big_f32) == "shuffle+zlib"  # dtype upgrade
    assert pol.resolve("opt/count", np.zeros((100_000,), np.int64)) == "zlib"
    assert pol.resolve("fields/mask", np.zeros((100_000,), np.int32)) == "zlib"  # lossy→lossless
    assert pol.resolve("step", np.int64(3)) == "none"  # tiny: stays contiguous
    # the classmethod constructor coexists with the `default` codec field
    assert pol.default == "zlib"

    p = str(tmp_path / "run.th5")
    rng = np.random.default_rng(5)
    state = {
        "fields": {"u": (rng.integers(0, 256, (2048, 64)) / 256).astype(np.float32)},
        "params": {"w": rng.standard_normal((2048, 64)).astype(np.float32)},
        "step": np.int64(7),
    }
    with CheckpointManager(p, codec_policy=CodecPolicy.default()) as mgr:
        res = mgr.save(0, state)  # no per-call policy
        assert res.filter_stats.n_chunks > 0  # leaves actually went chunked
        assert res.compression_ratio > 1.0
        assert mgr.file.meta("/simulation/step_00000000/state/fields.u").codec == "int8-blockq"
        assert mgr.file.meta("/simulation/step_00000000/state/params.w").codec == "shuffle+zlib"
        step, got = mgr.restore(0)
        np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])  # lossless
        from repro.core.codecs import Int8BlockQCodec

        assert (
            np.abs(got["fields"]["u"] - state["fields"]["u"]).max()
            <= Int8BlockQCodec.tolerance(state["fields"]["u"])
        )
        # an explicit per-call policy still overrides the manager's
        res2 = mgr.save(1, state, codec_policy=CodecPolicy(default="none"))
        assert res2.filter_stats.n_chunks == 0
