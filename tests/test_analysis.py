"""Analysis layer: HLO shape parsing, roofline math, analytic FLOPs model."""

import numpy as np
import pytest

from repro.analysis import flops as aflops
from repro.analysis import roofline as rf
from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.models.common import active_params_per_token, count_params


def test_shape_bytes_parsing():
    assert rf.shape_bytes("f32[16,4096]{1,0}") == 16 * 4096 * 4
    assert rf.shape_bytes("bf16[8]") == 16
    assert rf.shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert rf.shape_bytes("pred[10]") == 10
    assert rf.shape_bytes("f32[]") == 4  # scalar
    assert rf.shape_bytes("token[]") == 0


def test_parse_collectives_trip_scaling():
    hlo = """
HloModule test

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %arg = (s32[], f32[8]) parameter(0)
  %ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %ag = f32[32]{0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={0}
}
"""
    stats = rf.parse_collectives(hlo, 8)
    assert stats.op_counts["all-reduce"] == 5  # trip-scaled
    assert stats.op_counts["all-gather"] == 1
    # all-reduce wire = 2*(3/4)*32 bytes * 5 trips
    np.testing.assert_allclose(stats.wire_bytes["all-reduce"], 2 * 0.75 * 32 * 5)
    # all-gather wire = (3/4)*out(128 bytes), group size 4 from iota
    np.testing.assert_allclose(stats.wire_bytes["all-gather"], 0.75 * 128)
    assert stats.f32_wire_bytes == stats.total_wire_bytes  # all f32 here
    np.testing.assert_allclose(stats.wire_bytes_tpu_adjusted, 0.5 * stats.total_wire_bytes)


def test_roofline_terms_and_bottleneck():
    t = rf.roofline(
        flops_per_chip=197e12,  # exactly one second of compute
        hbm_bytes_per_chip=819e9 / 2,
        wire_bytes_per_chip=50e9 / 4,
        n_chips=256,
        model_flops_global=197e12 * 256 * 0.5,
    )
    np.testing.assert_allclose(t.compute_s, 1.0)
    np.testing.assert_allclose(t.memory_s, 0.5)
    np.testing.assert_allclose(t.collective_s, 0.25)
    assert t.bottleneck == "compute"
    np.testing.assert_allclose(t.useful_flops_frac, 0.5)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-2.7b", "mixtral-8x7b", "gemma3-1b"])
def test_analytic_flops_close_to_6nd(arch):
    """Train-cell layer FLOPs ≈ 6·N_active·tokens within the expected
    envelope (attention/SSD quadratic terms + remat on top, embeddings off)."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    n_active = active_params_per_token(cfg)
    fl = aflops.cell_flops(cfg, shape.global_batch, shape.seq_len, "train")
    six_nd = 6.0 * n_active * shape.global_batch * shape.seq_len
    ratio = fl["total"] / six_nd
    # remat=full → ×4/3 on layers; + attention/router terms; head counted in 6ND
    assert 0.9 < ratio < 2.5, ratio


def test_decode_flops_scale_with_cache():
    cfg = get_config("qwen3-8b")
    f_small = aflops.cell_flops(cfg, 128, 1, "decode", cache_len=1024)["total"]
    f_big = aflops.cell_flops(cfg, 128, 1, "decode", cache_len=32768)["total"]
    assert f_big > f_small  # attention term grows with T
    # but both dominated by the 2·N·B term
    assert f_big < 3 * f_small


def test_cache_bytes_ring_vs_full():
    g = get_config("gemma3-1b")
    full = aflops.cache_bytes(g.scaled(local_window=0), 1, 524_288)
    ring = aflops.cache_bytes(g, 1, 524_288)
    assert ring < 0.35 * full  # 5:1 local layers hold only 512-slot rings


def test_count_params_consistency_all():
    from repro.configs import ARCHS

    for a in ARCHS:
        cfg = get_config(a)
        n = count_params(cfg)
        na = active_params_per_token(cfg)
        assert 0 < na <= n
