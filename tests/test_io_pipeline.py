"""Zero-copy double-buffered pipeline: short-write resume, IOV_MAX chunking,
copy accounting, vectored scatter-reads, prefetch, plan cache."""

import os
import threading

import numpy as np
import pytest

from repro.core import aggregation
from repro.core.aggregation import (
    COPY_COUNTER,
    AggregationConfig,
    CollectiveWriter,
    WriteRequest,
    assign_file_domains,
    nd_slab_requests,
    pwritev_run,
)
from repro.core.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.core.container import READ_COUNTER, TH5File, _advance, preadv_full
from repro.core.sliding_window import WindowPrefetcher, iter_lod_windows


# -- short-write resume (aggregation._advance + pwritev_run) -------------------


def test_advance_drops_prefix_bytes():
    bufs = [memoryview(b"abcd"), memoryview(b"efg"), memoryview(b"hi")]
    assert _advance(bufs, 0) is bufs
    assert b"".join(_advance(bufs, 3)) == b"defghi"
    assert b"".join(_advance(bufs, 4)) == b"efghi"
    assert b"".join(_advance(bufs, 6)) == b"ghi"
    assert b"".join(_advance(bufs, 9)) == b""
    # aggregation re-exports the same helper (short-write resume lives once)
    assert aggregation._advance is _advance


def _capped_pwritev(cap):
    real = os.pwritev

    def fake(fd, bufs, offset):
        take, left = [], cap
        for b in bufs:
            if left <= 0:
                break
            mv = memoryview(b)
            take.append(mv[:left])
            left -= len(take[-1])
        return real(fd, take, offset)

    return fake


def test_pwritev_run_resumes_short_writes(tmp_path, monkeypatch):
    rng = np.random.default_rng(0)
    payload = [rng.integers(0, 255, 10, dtype=np.uint8) for _ in range(5)]
    reqs = [WriteRequest(i * 10, p) for i, p in enumerate(payload)]
    path = str(tmp_path / "short.bin")
    fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
    try:
        monkeypatch.setattr(os, "pwritev", _capped_pwritev(7))
        wrote, calls = pwritev_run(fd, 0, reqs)
    finally:
        os.close(fd)
    assert wrote == 50
    assert calls == -(-50 // 7)  # every syscall was short: ceil(50/7) calls
    with open(path, "rb") as f:
        assert f.read() == b"".join(p.tobytes() for p in payload)


def test_pwritev_run_chunks_beyond_iov_max(tmp_path, monkeypatch):
    monkeypatch.setattr(aggregation, "_IOV_MAX", 4)
    reqs = [WriteRequest(i * 3, bytes([i % 251]) * 3) for i in range(21)]
    path = str(tmp_path / "iov.bin")
    fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
    try:
        wrote, calls = pwritev_run(fd, 0, reqs)
    finally:
        os.close(fd)
    assert wrote == 63
    assert calls == -(-21 // 4)  # one syscall per 4-buffer chunk
    with open(path, "rb") as f:
        assert f.read() == b"".join(bytes([i % 251]) * 3 for i in range(21))


def test_pwritev_run_large_request_list_unpatched(tmp_path):
    """> real IOV_MAX (1024) requests in one coalesced run."""
    n = 1500
    reqs = [WriteRequest(i, bytes([i % 256])) for i in range(n)]
    path = str(tmp_path / "big.bin")
    fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
    try:
        wrote, calls = pwritev_run(fd, 0, reqs)
    finally:
        os.close(fd)
    assert wrote == n
    assert calls >= 2  # at least two IOV_MAX batches
    with open(path, "rb") as f:
        assert f.read() == bytes(i % 256 for i in range(n))


def test_short_write_resume_through_collective_writer(tmp_path, monkeypatch):
    """End-to-end: coalesced collective write survives short pwritev."""
    counts = [3, 5, 2]
    rng = np.random.default_rng(1)
    payload = [rng.integers(0, 255, (c, 16), dtype=np.uint8) for c in counts]
    path = str(tmp_path / "cw.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/x", (10, 16), "<u1")
        off = 0
        reqs = []
        for p in payload:
            reqs.append([WriteRequest(meta.offset + off, p)])
            off += p.nbytes
        monkeypatch.setattr(os, "pwritev", _capped_pwritev(13))
        with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=2)) as w:
            stats = w.write_collective(reqs)
        monkeypatch.undo()
        f.commit()
    assert stats.bytes_written == 160
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/x"), np.concatenate(payload))


# -- zero-copy accounting ------------------------------------------------------


def test_tp_sharded_nd_slab_is_zero_copy_at_32_ranks(tmp_path):
    """Acceptance: the coalesced zero-copy path issues ZERO payload copies in
    the TP-sharded (inner-dim) layout at 32 ranks."""
    rows, cols, n_ranks = 64, 256, 32
    cpr = cols // n_ranks
    rng = np.random.default_rng(2)
    shards = [np.ascontiguousarray(rng.random((rows, cpr), np.float32)) for _ in range(n_ranks)]
    path = str(tmp_path / "tp.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/w", (rows, cols), "<f4")
        COPY_COUNTER.reset()
        reqs = [
            nd_slab_requests(
                meta.offset, (rows, cols), 4,
                (slice(0, rows), slice(r * cpr, (r + 1) * cpr)), shards[r],
            )
            for r in range(n_ranks)
        ]
        with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=8)) as w:
            stats = w.write_collective(reqs)
        n_copies, bytes_copied = COPY_COUNTER.snapshot()
        f.commit()
    assert n_copies == 0 and bytes_copied == 0
    assert stats.n_copies == 0 and stats.bytes_copied == 0
    assert stats.bytes_written == rows * cols * 4
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/w"), np.concatenate(shards, axis=1))


def test_nd_slab_stride_aware_views_from_parent_array():
    """An inner-dim slice of a larger array (non-contiguous overall, rows
    individually contiguous) must still produce zero-copy requests."""
    parent = np.arange(16 * 12, dtype=np.int32).reshape(16, 12)
    shard = parent[:, 4:8]  # NOT C-contiguous; each row IS contiguous
    assert not shard.flags.c_contiguous
    COPY_COUNTER.reset()
    reqs = nd_slab_requests(0, (16, 12), 4, (slice(0, 16), slice(4, 8)), shard)
    assert COPY_COUNTER.snapshot() == (0, 0)
    assert len(reqs) == 16
    for i, r in enumerate(reqs):
        assert r.nbytes == 16
        view = r.data
        assert isinstance(view, np.ndarray) and view.base is not None
        np.testing.assert_array_equal(view, parent[i, 4:8])


def test_copy_counter_tracks_payload_materialisation():
    COPY_COUNTER.reset()
    r = WriteRequest(0, np.zeros(10, np.uint8))
    r.payload()
    assert COPY_COUNTER.snapshot() == (1, 10)
    WriteRequest(0, b"abc").payload()  # bytes payloads are free
    assert COPY_COUNTER.snapshot() == (1, 10)


# -- file domains --------------------------------------------------------------


def test_assign_file_domains_balanced_and_ordered():
    reqs = [WriteRequest(i * 10, bytes(10)) for i in range(8)]
    domains = assign_file_domains(list(reversed(reqs)), 4)
    assert len(domains) == 4
    assert [len(d) for d in domains] == [2, 2, 2, 2]
    flat = [r.offset for d in domains for r in d]
    assert flat == sorted(flat)
    # never more domains than aggregators even with awkward sizes
    assert len(assign_file_domains(reqs, 3)) == 3


def test_file_domains_coalesce_tp_layout_into_fewer_syscalls(tmp_path):
    """Rank bucketing fragments column-sharded writes; file domains stitch
    whole rows back together → strictly fewer syscalls."""
    rows, cols, n_ranks = 32, 64, 16
    cpr = cols // n_ranks
    rng = np.random.default_rng(3)
    shards = [np.ascontiguousarray(rng.random((rows, cpr), np.float32)) for r in range(n_ranks)]

    def write(path, file_domains):
        with TH5File.create(path) as f:
            meta = f.create_dataset("/w", (rows, cols), "<f4")
            reqs = [
                nd_slab_requests(
                    meta.offset, (rows, cols), 4,
                    (slice(0, rows), slice(r * cpr, (r + 1) * cpr)), shards[r],
                )
                for r in range(n_ranks)
            ]
            cfg = AggregationConfig(n_aggregators=4, file_domains=file_domains)
            with CollectiveWriter(f.fd, cfg) as w:
                stats = w.write_collective(reqs)
            f.commit()
        return stats

    s_dom = write(str(tmp_path / "dom.th5"), True)
    s_rank = write(str(tmp_path / "rank.th5"), False)
    assert s_dom.bytes_written == s_rank.bytes_written == rows * cols * 4
    assert s_dom.n_syscalls < s_rank.n_syscalls
    with TH5File.open(str(tmp_path / "dom.th5")) as f1, TH5File.open(
        str(tmp_path / "rank.th5")
    ) as f2:
        np.testing.assert_array_equal(f1.read("/w"), f2.read("/w"))
        np.testing.assert_array_equal(f1.read("/w"), np.concatenate(shards, axis=1))


# -- persistent pool + async submission ----------------------------------------


def test_persistent_aggregator_pool_reused_across_steps(tmp_path):
    with TH5File.create(str(tmp_path / "p.th5")) as f:
        meta = f.create_dataset("/x", (8, 64), "<u1")
        data = np.ones((4, 64), np.uint8)
        reqs = [[WriteRequest(meta.offset, data)], [WriteRequest(meta.offset + data.nbytes, data)]]
        with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=2)) as w:
            w.write_collective(reqs)
            pool = w._pool
            assert pool is not None
            w.write_collective(reqs)
            assert w._pool is pool  # no per-step spawn/teardown
        assert w._pool is None  # context exit releases the threads


def test_submit_collective_overlaps_with_caller(tmp_path):
    rng = np.random.default_rng(4)
    data = rng.integers(0, 255, (64, 128), dtype=np.uint8)
    path = str(tmp_path / "a.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/x", data.shape, "<u1")
        with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=2)) as w:
            fut = w.submit_collective([[WriteRequest(meta.offset, data)]])
            stats = fut.result(timeout=30)
        assert stats.bytes_written == data.nbytes
        f.commit()
    with TH5File.open(path) as f:
        np.testing.assert_array_equal(f.read("/x"), data)


# -- vectored scatter reads ----------------------------------------------------


def test_preadv_full_scatter_and_short_resume(tmp_path, monkeypatch):
    path = str(tmp_path / "r.bin")
    blob = bytes(range(256)) * 4
    with open(path, "wb") as f:
        f.write(blob)
    fd = os.open(path, os.O_RDONLY)
    try:
        a = np.zeros(100, np.uint8)
        b = np.zeros(156, np.uint8)
        real = os.preadv

        def short_preadv(fd_, bufs, off):
            bufs = [memoryview(x)[:37] for x in bufs[:1]]  # 37 bytes max
            return real(fd_, bufs, off)

        monkeypatch.setattr(os, "preadv", short_preadv)
        n, calls = preadv_full(fd, [memoryview(a), memoryview(b)], 0)
    finally:
        os.close(fd)
    assert n == 256
    # 37-byte short reads never cross a buffer boundary in the fake:
    # a → 37+37+26, b → 37·4+8 = 8 resumed syscalls
    assert calls == 8
    assert bytes(a) + bytes(b) == blob[:256]


def test_read_row_indices_vectored_scatter(tmp_path):
    rng = np.random.default_rng(5)
    data = rng.random((64, 7), np.float64)
    path = str(tmp_path / "s.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/d", data.shape, "<f8")
        f.write_full(meta, data)
        f.commit()
        # unsorted, with duplicates and contiguous runs
        idx = [5, 3, 4, 40, 41, 42, 3, 63, 0]
        READ_COUNTER.reset()
        got = f.read_row_indices("/d", idx)
        syscalls, nbytes = READ_COUNTER.snapshot()
        np.testing.assert_array_equal(got, data[idx])
        # runs: [0],[3],[3,4,5],[40..42],[63] → 5 coalesced preadv calls
        assert syscalls == 5
        assert nbytes == len(idx) * 7 * 8
        with pytest.raises(Exception):
            f.read_row_indices("/d", [64])


def test_read_rows_into_preallocated(tmp_path):
    data = np.arange(48, dtype=np.float32).reshape(12, 4)
    path = str(tmp_path / "ri.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/d", data.shape, "<f4")
        f.write_full(meta, data)
        f.commit()
        out = np.empty((5, 4), np.float32)
        n = f.read_rows_into("/d", 3, 5, out)
        assert n == 5 * 4 * 4
        np.testing.assert_array_equal(out, data[3:8])
        with pytest.raises(Exception):
            f.read_rows_into("/d", 0, 5, np.empty((4, 4), np.float32))


def test_zero_sized_reads_and_writes(tmp_path):
    """Empty extents must round-trip, not crash in the byte-view casts."""
    path = str(tmp_path / "z.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/empty", (0, 4), "<f4")
        assert f.read("/empty").shape == (0, 4)
        assert f.read_rows("/empty", 0, 0).shape == (0, 4)
        # empty write request through the collective path writes 0 bytes
        reqs = nd_slab_requests(
            meta.offset, (8, 4), 4, (slice(0, 0), slice(0, 4)), np.empty((0, 4), np.float32)
        )
        with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=2)) as w:
            stats = w.write_collective([reqs])
        assert stats.bytes_written == 0
        f.commit()
    # elastic restore with more ranks than rows → this rank owns 0 rows
    mgr = CheckpointManager(str(tmp_path / "e.th5"))
    mgr.save(0, {"w": np.ones((4, 2), np.float32)}, n_ranks=2)
    shard = mgr.restore_leaf_shard(0, "w", rank=5, n_ranks=8)
    assert shard.shape == (0, 2)
    mgr.close()


def test_write_stats_copies_not_polluted_by_concurrent_planning(tmp_path):
    """Per-write copy stats must ignore copies made by other threads during
    the write window (the double-buffer overlap submit_collective enables)."""
    data = np.zeros((512, 64), np.uint8)
    path = str(tmp_path / "cc.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/x", data.shape, "<u1")
        stop = threading.Event()

        def churn():  # a "step n+1 planner" making copies concurrently
            junk = WriteRequest(0, np.ones(64, np.uint8))
            while not stop.is_set():
                junk.payload()

        t = threading.Thread(target=churn, daemon=True)
        t.start()
        try:
            with CollectiveWriter(f.fd, AggregationConfig(n_aggregators=2)) as w:
                for _ in range(5):
                    stats = w.write_collective([[WriteRequest(meta.offset, data)]])
                    assert stats.n_copies == 0 and stats.bytes_copied == 0
        finally:
            stop.set()
            t.join()
        f.commit()


# -- prefetcher ----------------------------------------------------------------


def test_window_prefetcher_matches_direct_gather(tmp_path):
    rng = np.random.default_rng(6)
    data = rng.random((100, 3), np.float32)
    path = str(tmp_path / "w.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/d", data.shape, "<f4")
        f.write_full(meta, data)
        f.commit()
        windows = [list(range(i, i + 10)) for i in range(0, 90, 5)]
        with WindowPrefetcher(f, "/d") as pf:
            got = list(pf.iter_windows(windows))
        assert len(got) == len(windows)
        for g, w in zip(got, windows):
            np.testing.assert_array_equal(g, data[w])
        # empty window sequence is fine
        with WindowPrefetcher(f, "/d") as pf:
            assert list(pf.iter_windows([])) == []


def test_iter_lod_windows_budget(tmp_path):
    data = np.arange(200, dtype=np.float32).reshape(100, 2)
    path = str(tmp_path / "l.th5")
    with TH5File.create(path) as f:
        meta = f.create_dataset("/d", data.shape, "<f4")
        f.write_full(meta, data)
        f.commit()
        got = list(iter_lod_windows(f, "/d", [(0, 100), (50, 60)], max_rows=25))
        assert len(got[0]) <= 25  # stride-decimated to the budget
        np.testing.assert_array_equal(got[1], data[50:60])  # fits, stride 1


# -- plan cache + double-buffered checkpointing --------------------------------


def _state(seed):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.random((32, 8), np.float32),
        "b": rng.random((32,), np.float32),
    }


def test_plan_cache_hits_on_static_topology(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c.th5"))
    mgr.save(0, _state(0), n_ranks=4)
    info0 = mgr.plan_cache_info()
    assert info0["hits"] == 0 and info0["misses"] == 2  # two distinct leaf plans
    mgr.save(1, _state(1), n_ranks=4)
    info1 = mgr.plan_cache_info()
    assert info1["misses"] == 2  # static topology: no re-planning at all
    assert info1["hits"] == 2
    s0, t0 = mgr.restore(0)[1], _state(0)
    np.testing.assert_array_equal(s0["w"], t0["w"])
    s1, t1 = mgr.restore(1)[1], _state(1)
    np.testing.assert_array_equal(s1["b"], t1["b"])
    mgr.close()


def test_double_buffered_async_checkpointer(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "d.th5"))
    ac = AsyncCheckpointer(mgr)
    assert ac.double_buffer
    for step in range(3):
        ac.save(step, _state(step), n_ranks=2)
    ac.wait()
    for step in range(3):
        got = mgr.restore(step)[1]
        np.testing.assert_array_equal(got["w"], _state(step)["w"])
    mgr.close()


def test_async_checkpointer_single_buffer_mode(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "sb.th5"))
    ac = AsyncCheckpointer(mgr, double_buffer=False)
    ac.save(0, _state(0))
    ac.save(1, _state(1))
    ac.wait()
    np.testing.assert_array_equal(mgr.restore(1)[1]["b"], _state(1)["b"])
    mgr.close()


def test_device_pack_linear_does_not_retrace():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.collective_io import _pack_linear, device_pack_linear

    bufs = [jnp.ones((4, 2), jnp.float32), jnp.arange(3, dtype=jnp.int32)]
    a = device_pack_linear(bufs)
    b = device_pack_linear([x + 0 for x in bufs])
    assert a.shape == b.shape == (4 * 2 * 4 + 3 * 4,)
    if hasattr(_pack_linear, "_cache_size"):
        assert _pack_linear._cache_size() == 1  # same signature → one trace
