"""Device-side planner (psum + exscan under shard_map) == host planner."""

import numpy as np

from tests._subproc import run_with_devices

CODE = r"""
import numpy as np
import jax
from repro.core.collective_io import collective_plan, gather_to_aggregators
from repro.core.hyperslab import plan_rows

from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("io",))
counts = np.array([5, 0, 3, 7, 1, 1, 9, 2], dtype=np.int32)

total, starts = collective_plan(mesh, "io", counts)
plan = plan_rows(counts, 1)
assert total == plan.total_rows, (total, plan.total_rows)
np.testing.assert_array_equal(starts, plan.row_starts)

# gather_to_aggregators: each shard ends up with its group's rows
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
xs = jax.device_put(x, NamedSharding(mesh, P("io")))
g = gather_to_aggregators(mesh, "io", n_aggregators=2, x=xs)
g = np.asarray(g)
# shard i (rows i*4:(i+1)*4 of output) holds group (i//4)'s 4 source rows
for shard in range(8):
    grp = shard // 4
    want = np.arange(8 * 4, dtype=np.float32).reshape(8, 4)[grp * 4:(grp + 1) * 4]
    np.testing.assert_array_equal(g[shard * 4:(shard + 1) * 4], want)
print("OK")
"""


def test_collective_plan_matches_host_planner():
    out = run_with_devices(CODE, 8)
    assert "OK" in out


def test_single_device_plan():
    """Degenerate mesh of 1 — must still agree (runs in-process, 1 device)."""
    import jax

    from repro.core.collective_io import collective_plan
    from repro.core.hyperslab import plan_rows

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("io",))
    total, starts = collective_plan(mesh, "io", np.array([13], dtype=np.int32))
    plan = plan_rows([13], 1)
    assert total == plan.total_rows
    np.testing.assert_array_equal(starts, plan.row_starts)
