"""Time-Reversible Steering: branch lineage, overlays, reads through chain."""

import numpy as np
import pytest

from repro.core.checkpoint import CheckpointManager
from repro.core.steering import BranchManager


def _mk(tmp_path, name, common=None):
    return CheckpointManager(str(tmp_path / name), common=common or {})


def test_branch_and_restore_through_parent(tmp_path):
    root = _mk(tmp_path, "root.th5", common={"lamp_T": 324.66})
    for s in (10, 20, 30):
        root.save(s, {"T": np.full(4, float(s), np.float32)})
    bm = BranchManager(root)

    # roll back to t=20, raise lamp temperature by 50 K (the paper's scenario)
    child = bm.branch(20, str(tmp_path / "branch.th5"), overlay={"lamp_T": 374.66})
    assert child.effective_config()["lamp_T"] == 374.66
    # parent snapshots ≤ 20 are visible, 30 is not (it is the abandoned future)
    assert child.available_steps() == [10, 20]
    step, st = child.restore(20)
    assert step == 20
    np.testing.assert_array_equal(st["T"], np.full(4, 20.0, np.float32))

    # continue the branch
    child.manager.save(25, {"T": np.full(4, 25.0, np.float32)})
    child.manager.save(35, {"T": np.full(4, 35.0, np.float32)})
    assert child.available_steps() == [10, 20, 25, 35]
    _, st35 = child.restore(35)
    np.testing.assert_array_equal(st35["T"], np.full(4, 35.0, np.float32))
    root.close()
    child.manager.close()


def test_two_level_lineage_visibility(tmp_path):
    root = _mk(tmp_path, "root.th5")
    for s in (1, 2, 3, 4):
        root.save(s, {"x": np.full(2, float(s))})
    b1 = BranchManager(root).branch(3, str(tmp_path / "b1.th5"), overlay={"lr": 0.1})
    b1.manager.save(4, {"x": np.full(2, 40.0)})  # rewrites step 4 in the branch
    b1.manager.save(5, {"x": np.full(2, 50.0)})
    b2 = b1.branch(4, str(tmp_path / "b2.th5"), overlay={"lr": 0.01})

    # b2 sees: root steps <= 3, b1's steps <= 4 (not 5)
    assert b2.available_steps() == [1, 2, 3, 4]
    _, s4 = b2.restore(4)
    np.testing.assert_array_equal(s4["x"], np.full(2, 40.0))  # b1's version wins
    _, s2 = b2.restore(2)
    np.testing.assert_array_equal(s2["x"], np.full(2, 2.0))  # from root
    # overlays compose root→leaf
    assert b2.effective_config()["lr"] == 0.01
    lineage = b2.lineage()
    assert [e.branch_step for e in lineage] == [None, 3, 4]
    root.close()
    b1.manager.close()
    b2.manager.close()


def test_branch_at_missing_step_rejected(tmp_path):
    root = _mk(tmp_path, "root.th5")
    root.save(1, {"x": np.zeros(2)})
    with pytest.raises(KeyError):
        BranchManager(root).branch(99, str(tmp_path / "bad.th5"))
    root.close()


def test_restore_missing_step_raises(tmp_path):
    root = _mk(tmp_path, "root.th5")
    root.save(1, {"x": np.zeros(2)})
    bm = BranchManager(root)
    with pytest.raises(KeyError):
        bm.restore(7)
    root.close()


def test_branch_is_cheap_no_data_copy(tmp_path):
    """Rollback must be metadata-only: branch file stays tiny even when the
    parent holds megabytes (paper: reload 'in rapid fashion')."""
    import os

    root = _mk(tmp_path, "root.th5")
    root.save(1, {"x": np.zeros((512, 1024), np.float32)})  # 2 MiB
    bm = BranchManager(root).branch(1, str(tmp_path / "b.th5"))
    bm.manager.file.commit()
    assert os.path.getsize(str(tmp_path / "b.th5")) < 64 * 1024
    root.close()
    bm.manager.close()
