"""UID pack/unpack + Morton code properties."""

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import uid


@given(
    rank=st.integers(min_value=0, max_value=uid.RANK_MAX),
    local=st.integers(min_value=0, max_value=uid.LOCAL_MAX),
    depth=st.integers(min_value=0, max_value=uid.DEPTH_MAX),
    morton=st.integers(min_value=0, max_value=uid.MORTON_MAX),
)
@settings(max_examples=200)
def test_pack_unpack_roundtrip(rank, local, depth, morton):
    u = uid.pack(rank, local, depth, morton)
    assert 0 <= u < 2**64
    assert uid.unpack(u) == (rank, local, depth, morton)
    assert uid.rank_of(u) == rank


def test_pack_bounds():
    with pytest.raises(ValueError):
        uid.pack(uid.RANK_MAX + 1, 0, 0, 0)
    with pytest.raises(ValueError):
        uid.pack(0, 0, uid.DEPTH_MAX + 1, 0)


@given(
    rank=st.lists(st.integers(min_value=0, max_value=uid.RANK_MAX), min_size=1, max_size=64),
)
@settings(max_examples=50)
def test_pack_array_matches_scalar(rank):
    n = len(rank)
    rng = np.random.default_rng(0)
    locals_ = rng.integers(0, uid.LOCAL_MAX, n)
    depths = rng.integers(0, uid.DEPTH_MAX, n)
    mortons = rng.integers(0, uid.MORTON_MAX, n)
    arr = uid.pack_array(np.array(rank), locals_, depths, mortons)
    for i in range(n):
        assert int(arr[i]) == uid.pack(rank[i], int(locals_[i]), int(depths[i]), int(mortons[i]))
    r2, l2, d2, m2 = uid.unpack_array(arr)
    np.testing.assert_array_equal(r2.astype(np.int64), rank)
    np.testing.assert_array_equal(l2, locals_)
    np.testing.assert_array_equal(d2, depths)
    np.testing.assert_array_equal(m2, mortons)


@given(
    i=st.integers(min_value=0, max_value=1023),
    j=st.integers(min_value=0, max_value=1023),
    k=st.integers(min_value=0, max_value=1023),
)
@settings(max_examples=200)
def test_morton_roundtrip(i, j, k):
    code = uid.morton3(i, j, k)
    ii, jj, kk = uid.morton3_inverse(code)
    assert (int(ii), int(jj), int(kk)) == (i, j, k)


def test_morton_locality():
    """Adjacent cells differ in few high bits — SFC neighbour preservation."""
    c000 = int(uid.morton3(0, 0, 0))
    c100 = int(uid.morton3(1, 0, 0))
    assert c100 == 1  # x is the lowest interleaved bit
    assert int(uid.morton3(0, 1, 0)) == 2
    assert int(uid.morton3(0, 0, 1)) == 4
    assert c000 == 0
