"""The observability plane (``repro.obs``) and its threading through the
stack.

The contract under test: spans survive explicit pool handoff in both
filter pipelines (recorded from worker threads under the submitting
trace), one remote request stitches into ONE trace shared by client,
broker and decode spans, the disabled tracer's hot path allocates nothing
beyond the no-op guard, the unified registry sees the pre-existing
counters without breaking their local-instance semantics, the Chrome
export is loadable trace-event JSON, and the broker's slow-request log
dumps a span tree over the threshold.  Plus the LatencyRecorder
regression: percentile queries are read-only and one snapshot sorts once.
"""

import gc
import json
import logging
import os
import sys
import threading

import numpy as np
import pytest

from repro.core.aggregation import (
    AggregationConfig,
    ChunkPipeline,
    CopyCounter,
    COPY_COUNTER,
)
from repro.core.container import ReadCounter, READ_COUNTER, ChunkCache, TH5File
from repro.obs import (
    NOOP_SPAN,
    REGISTRY,
    TRACER,
    MetricsRegistry,
    Tracer,
    chrome_trace_events,
    format_span_tree,
    prometheus_text,
    write_chrome_trace,
)
from repro.obs.metrics import (
    M_CACHE_HITS,
    M_CACHE_MISSES,
    M_SLOW_REQUESTS,
)
from repro.obs.trace import (
    SPAN_BROKER_REQUEST,
    SPAN_CLIENT_REQUEST,
    SPAN_DECODE_GATHER,
    SPAN_DECODE_INFLATE,
    SPAN_ENCODE_CHUNK,
    SPAN_EXECUTE,
    SPAN_QUEUE_WAIT,
    SPAN_SCHEDULE,
    SPAN_WIRE_SEND,
    SpanContext,
)
from repro.service import (
    DataService,
    RemoteDataService,
    ServiceConfig,
    ServiceServer,
    WindowQuery,
)
from repro.service import wire
from repro.service.stats import LatencyRecorder

ROWS, COLS, CHUNK_ROWS = 1024, 32, 128
DS = "/simulation/step_00000000/state/fields/u"


@pytest.fixture(autouse=True)
def _quiet_tracer():
    """Every test starts and ends with the process tracer disabled and
    empty (other suites must never see our spans)."""
    TRACER.configure(enabled=False, sample_every=1)
    TRACER.reset()
    yield
    TRACER.configure(enabled=False, sample_every=1)
    TRACER.reset()


@pytest.fixture()
def run_file(tmp_path):
    rng = np.random.default_rng(11)
    u = rng.standard_normal((ROWS, COLS)).astype(np.float32)
    path = str(tmp_path / "run.th5")
    with TH5File.create(path) as f:
        mu = f.create_chunked_dataset(DS, u.shape, "<f4", CHUNK_ROWS, "shuffle+zlib")
        with ChunkPipeline(f, AggregationConfig(n_aggregators=2)) as pipe:
            pipe.write(mu, u)
        f.commit()
    return path, u


# -- tracer core ---------------------------------------------------------------


def test_span_lifecycle_and_tree():
    tr = Tracer(enabled=True)
    root = tr.start_trace("client.request")
    assert root.trace_id and root.parent_id == 0
    with tr.use(root):
        with tr.span("decode.gather") as g:
            g.tag("chunks", 2)
            tr.record("decode.fetch", g, g.t0, g.t0 + 0.001, {"nbytes": 64})
    root.end()
    spans = tr.snapshot()
    assert [s.name for s in spans] == ["decode.fetch", "decode.gather", "client.request"]
    assert len({s.trace_id for s in spans}) == 1
    tree = format_span_tree(spans)
    # child indentation: gather under the root, fetch under gather
    assert tree.index("client.request") < tree.index("decode.gather") < tree.index("decode.fetch")
    assert "chunks=2" in tree and "nbytes=64" in tree


def test_span_end_is_idempotent():
    tr = Tracer(enabled=True)
    s = tr.start_trace("x")
    s.end()
    t1 = s.t1
    s.end()
    assert s.t1 == t1 and len(tr) == 1


def test_child_without_sampled_parent_is_noop():
    tr = Tracer(enabled=True)
    # no ambient context, no explicit parent → never a stray root
    assert tr.span("decode.gather") is NOOP_SPAN
    # a NOOP parent propagates NOOP-ness
    assert tr.span("decode.fetch", NOOP_SPAN) is NOOP_SPAN


def test_deterministic_sampling_counter_not_rng():
    tr = Tracer(enabled=True, sample_every=3)
    kept = [bool(tr.start_trace("r").trace_id) for _ in range(9)]
    assert kept == [True, False, False] * 3
    tr2 = Tracer(enabled=True, sample_every=3)
    assert [bool(tr2.start_trace("r").trace_id) for _ in range(9)] == kept


def test_ring_is_bounded():
    tr = Tracer(enabled=True, capacity=8)
    for _ in range(50):
        tr.start_trace("r").end()
    assert len(tr) == 8
    assert len(tr.drain()) == 8 and len(tr) == 0


def test_explicit_context_crosses_threads():
    tr = Tracer(enabled=True)
    root = tr.start_trace("client.request")
    ctx = root.context
    main = threading.get_ident()

    def worker():
        tr.record("decode.inflate", ctx, 1.0, 2.0)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    root.end()
    inflate = [s for s in tr.snapshot() if s.name == "decode.inflate"][0]
    assert inflate.trace_id == root.trace_id
    assert inflate.parent_id == root.span_id
    assert inflate.thread != main  # recorded on the other thread


def test_disabled_tracer_identity_and_zero_allocation():
    """The no-op path: same singleton every call, and a span/tag/end cycle
    on the hot path allocates no objects beyond the guard."""
    tr = Tracer()  # disabled
    assert tr.span("x") is NOOP_SPAN
    assert tr.start_trace("x") is NOOP_SPAN
    assert tr.current_context() is None
    loops = tuple(range(1000))  # pre-build the iterable outside the window
    # warmup (interns, thread-local init, method caches)
    for _ in loops:
        s = tr.span("x")
        s.tag("k", 1)
        s.end()
    gc.disable()
    try:
        base = sys.getallocatedblocks()
        for _ in loops:
            s = tr.span("x")
            s.tag("k", 1)
            s.end()
        delta = sys.getallocatedblocks() - base
    finally:
        gc.enable()
    # a handful of loop-constant blocks (iterator, frame caches) are fine;
    # anything per-call would show up 1000× here
    assert delta < 20, f"disabled-tracer hot path allocated {delta} blocks over 1000 spans"


# -- metrics registry ----------------------------------------------------------


def test_registry_instruments_and_collect():
    reg = MetricsRegistry()
    reg.counter("a.hits").inc(3)
    reg.gauge("a.depth").set(7)
    h = reg.histogram("a.lat")
    h.observe(0.5)
    h.observe(1.5)
    got = reg.collect()
    assert got["a.hits"] == 3 and got["a.depth"] == 7
    assert got["a.lat.count"] == 2 and got["a.lat.sum"] == 2.0
    assert got["a.lat.min"] == 0.5 and got["a.lat.max"] == 1.5
    assert h.mean == 1.0


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)


def test_collectors_sum_and_unregister():
    reg = MetricsRegistry()
    reg.counter("n").inc(1)
    fn = lambda: {"n": 2.0, "other": 5.0}  # noqa: E731
    reg.register_collector(fn)
    got = reg.collect()
    assert got["n"] == 3.0 and got["other"] == 5.0
    reg.unregister_collector(fn)
    assert reg.collect()["n"] == 1.0


def test_copy_and_read_counter_local_instances_stay_isolated():
    """The write paths build throwaway CopyCounter()s for per-call deltas;
    their adds and resets must not leak into the registered process
    totals (and vice versa)."""
    g0 = COPY_COUNTER.snapshot()
    local = CopyCounter()
    local.add(100)
    local.reset()
    local.add(40)
    assert local.snapshot() == (1, 40)
    assert COPY_COUNTER.snapshot() == g0
    r0 = READ_COUNTER.snapshot()
    lr = ReadCounter()
    lr.add(64, 2)
    assert lr.snapshot() == (2, 64)
    assert READ_COUNTER.snapshot() == r0


def test_chunk_cache_mirrors_into_registry():
    before = REGISTRY.collect()
    cache = ChunkCache(capacity_bytes=1 << 20)
    arr = np.zeros(16, dtype="<f4")
    assert cache.get(("/d", 0)) is None
    cache.put(("/d", 0), arr)
    assert cache.get(("/d", 0)) is not None
    after = REGISTRY.collect()
    assert after[M_CACHE_HITS] - before.get(M_CACHE_HITS, 0) == 1
    assert after[M_CACHE_MISSES] - before.get(M_CACHE_MISSES, 0) == 1
    # the instance's own stats stay local truth
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("cache.hits").inc(5)
    reg.gauge("queue.depth").set(1.25)
    text = prometheus_text(registry=reg)
    assert "# TYPE cache_hits gauge\ncache_hits 5" in text
    assert "queue_depth 1.25" in text
    assert text.endswith("\n")


# -- exporters -----------------------------------------------------------------


def test_chrome_trace_events_and_file(tmp_path):
    tr = Tracer(enabled=True)
    root = tr.start_trace("client.request")
    with tr.use(root):
        tr.span("decode.gather").tag("n", 1).end()
    root.end()
    events = chrome_trace_events(tr.snapshot(), pid=1234)
    assert all(e["ph"] == "X" and e["pid"] == 1234 for e in events)
    gather = [e for e in events if e["name"] == "decode.gather"][0]
    root_ev = [e for e in events if e["name"] == "client.request"][0]
    assert gather["args"]["trace_id"] == root_ev["args"]["trace_id"]
    assert gather["ts"] >= root_ev["ts"]  # µs, same clock domain
    path = str(tmp_path / "trace.json")
    n = write_chrome_trace(path, tracer=tr)
    assert n == 2
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    assert doc["displayTimeUnit"] == "ms"
    assert {e["name"] for e in doc["traceEvents"]} == {"client.request", "decode.gather"}


def test_span_tree_renders_orphans_as_roots():
    """A broker-side dump happens while the client's root span is still
    open on the other side of the socket: spans whose parent is absent
    must render as roots, not vanish."""
    tr = Tracer(enabled=True)
    ctx = SpanContext(0xABC, 999)  # parent 999 will never be in the buffer
    tr.record("broker.execute", ctx, 1.0, 2.0)
    tree = format_span_tree(tr.snapshot())
    assert "broker.execute" in tree


# -- pipeline pool handoff -----------------------------------------------------


def test_encode_spans_survive_pool_handoff(tmp_path):
    rng = np.random.default_rng(5)
    u = rng.standard_normal((ROWS, COLS)).astype(np.float32)
    path = str(tmp_path / "w.th5")
    TRACER.configure(enabled=True)
    main = threading.get_ident()
    with TH5File.create(path) as f:
        mu = f.create_chunked_dataset(DS, u.shape, "<f4", CHUNK_ROWS, "shuffle+zlib")
        root = TRACER.start_trace("bench.write")
        with TRACER.use(root):
            with ChunkPipeline(f, AggregationConfig(n_aggregators=2)) as pipe:
                pipe.write(mu, u)
        root.end()
    enc = [s for s in TRACER.snapshot() if s.name == SPAN_ENCODE_CHUNK]
    assert len(enc) == ROWS // CHUNK_ROWS
    assert all(s.trace_id == root.trace_id for s in enc)
    assert all(s.parent_id == root.span_id for s in enc)
    # the encodes genuinely ran on codec pool workers, not the caller
    assert any(s.thread != main for s in enc)


def test_decode_spans_survive_pool_handoff(run_file):
    path, u = run_file
    TRACER.configure(enabled=True)
    main = threading.get_ident()
    with TH5File.open(path) as f:
        f.chunk_cache.clear()
        root = TRACER.start_trace("bench.read")
        with TRACER.use(root):
            back = f.read_rows(DS, 0, ROWS)
        root.end()
    np.testing.assert_array_equal(back, u)
    spans = TRACER.snapshot()
    gathers = [s for s in spans if s.name == SPAN_DECODE_GATHER]
    inflates = [s for s in spans if s.name == SPAN_DECODE_INFLATE]
    assert len(gathers) == 1 and gathers[0].trace_id == root.trace_id
    assert len(inflates) == ROWS // CHUNK_ROWS
    assert all(s.trace_id == root.trace_id for s in inflates)
    # inflate ran in the decode pool — recorded from non-caller threads
    assert any(s.thread != main for s in inflates)
    assert gathers[0].tags["cache_misses"] == ROWS // CHUNK_ROWS


def test_untraced_reads_emit_no_spans(run_file):
    path, u = run_file
    TRACER.configure(enabled=True)  # enabled, but no root installed
    with TH5File.open(path) as f:
        f.chunk_cache.clear()
        f.read_rows(DS, 0, ROWS)
    assert len(TRACER) == 0  # children never out-sample their (absent) root


# -- service stitching ---------------------------------------------------------


def test_in_process_submit_records_phase_spans(run_file):
    path, _ = run_file
    TRACER.configure(enabled=True)
    with DataService(path, ServiceConfig(n_workers=2)) as svc:
        resp = svc.submit("cli", WindowQuery(dataset=DS, rows=(1, 2, 3))).result()
        assert resp.value.shape == (3, COLS)
    names = {s.name for s in TRACER.snapshot()}
    assert {SPAN_BROKER_REQUEST, SPAN_QUEUE_WAIT, SPAN_SCHEDULE, SPAN_EXECUTE} <= names
    roots = [s for s in TRACER.snapshot() if s.name == SPAN_BROKER_REQUEST]
    assert len({s.trace_id for s in TRACER.snapshot()}) == 1
    exe = [s for s in TRACER.snapshot() if s.name == SPAN_EXECUTE][0]
    assert exe.parent_id == roots[0].span_id
    assert exe.tags["type"] == "WindowQuery"


def test_remote_request_is_one_stitched_trace(run_file, tmp_path):
    """THE acceptance criterion: client + broker + decode spans of one
    remote request share a single trace_id."""
    import tempfile

    path, u = run_file
    TRACER.configure(enabled=True)
    with tempfile.TemporaryDirectory(prefix="th5o", dir="/tmp") as d:
        with DataService(path, ServiceConfig(n_workers=2)) as svc:
            svc.file.chunk_cache.clear()
            with ServiceServer(svc, os.path.join(d, "s.sock")) as server:
                with RemoteDataService(server.address) as remote:
                    rows = tuple(range(0, 300))
                    resp = remote.request("viewer", WindowQuery(dataset=DS, rows=rows))
                    np.testing.assert_array_equal(resp.value, u[list(rows)])
    spans = TRACER.snapshot()
    assert len({s.trace_id for s in spans}) == 1
    names = {s.name for s in spans}
    assert {
        SPAN_CLIENT_REQUEST,
        SPAN_QUEUE_WAIT,
        SPAN_SCHEDULE,
        SPAN_EXECUTE,
        SPAN_WIRE_SEND,
        SPAN_DECODE_GATHER,
        SPAN_DECODE_INFLATE,
    } <= names
    client_root = [s for s in spans if s.name == SPAN_CLIENT_REQUEST][0]
    assert client_root.parent_id == 0 and client_root.tags["ok"] is True
    # broker phases parent directly under the client's root: stitched, not
    # two traces glued by timestamps
    qw = [s for s in spans if s.name == SPAN_QUEUE_WAIT][0]
    assert qw.parent_id == client_root.span_id


def test_remote_requests_untraced_when_disabled(run_file, tmp_path):
    import tempfile

    path, _ = run_file
    with tempfile.TemporaryDirectory(prefix="th5o", dir="/tmp") as d:
        with DataService(path, ServiceConfig(n_workers=2)) as svc:
            with ServiceServer(svc, os.path.join(d, "s.sock")) as server:
                with RemoteDataService(server.address) as remote:
                    remote.request("viewer", WindowQuery(dataset=DS, rows=(0, 1)))
    assert len(TRACER) == 0


def test_slow_request_log_dumps_span_tree(run_file, caplog):
    path, _ = run_file
    TRACER.configure(enabled=True)
    slow0 = REGISTRY.collect().get(M_SLOW_REQUESTS, 0.0)
    with caplog.at_level(logging.WARNING, logger="repro.service.slowlog"):
        with DataService(path, ServiceConfig(n_workers=2, slow_request_s=0.0)) as svc:
            svc.submit("cli", WindowQuery(dataset=DS, rows=(0, 1, 2))).result()
    assert any("slow request" in r.message for r in caplog.records)
    dump = "\n".join(r.getMessage() for r in caplog.records)
    assert SPAN_QUEUE_WAIT in dump and SPAN_EXECUTE in dump  # the span tree
    assert REGISTRY.collect()[M_SLOW_REQUESTS] > slow0


def test_slow_request_log_untraced_phase_summary(run_file, caplog):
    path, _ = run_file  # tracer stays disabled
    with caplog.at_level(logging.WARNING, logger="repro.service.slowlog"):
        with DataService(path, ServiceConfig(n_workers=2, slow_request_s=0.0)) as svc:
            svc.submit("cli", WindowQuery(dataset=DS, rows=(0,))).result()
    msgs = [r.getMessage() for r in caplog.records if "slow request" in r.message]
    assert msgs and "queued=" in msgs[0] and "exec=" in msgs[0]


def test_broker_collector_reports_service_metrics(run_file):
    path, _ = run_file
    with DataService(path, ServiceConfig(n_workers=2)) as svc:
        svc.submit("cli", WindowQuery(dataset=DS, rows=(0, 1))).result()
        got = REGISTRY.collect()
        assert got["service.completed"] >= 1
        assert got["service.bytes_served"] >= 2 * COLS * 4
    # after close the collector is unregistered: no stale reads
    got2 = REGISTRY.collect()
    assert "service.inflight" not in got2 or got2["service.inflight"] == 0


# -- wire propagation helpers --------------------------------------------------


def test_wire_put_get_trace_roundtrip():
    meta = {"client": "c", "type": "WindowQuery"}
    wire.put_trace(meta, 0xDEAD, 7)
    ctx = wire.get_trace(json.loads(json.dumps(meta)))
    assert ctx == (0xDEAD, 7)


@pytest.mark.parametrize(
    "bad",
    [None, "nope", [1], [1, 2, 3], ["x", "y"], [0, 5], [-3, 5], {"a": 1}],
)
def test_wire_get_trace_rejects_malformed(bad):
    meta = {"client": "c"}
    if bad is not None:
        meta[wire.TRACE_KEY] = bad
    assert wire.get_trace(meta) is None


# -- LatencyRecorder regression (satellite 1) ----------------------------------


def test_percentile_queries_do_not_mutate_recorder_state():
    rec = LatencyRecorder(capacity=64)
    for v in (5.0, 1.0, 3.0, 2.0, 4.0):
        rec.add(v)
    raw_before = list(rec._samples)
    seen_before = rec.n
    for _ in range(3):
        rec.percentile(50)
        rec.percentiles(50, 90, 99)
    assert list(rec._samples) == raw_before  # insertion order intact
    assert rec.n == seen_before


def test_percentiles_single_sort_matches_individual_queries():
    rec = LatencyRecorder(capacity=128)
    rng = np.random.default_rng(3)
    for v in rng.random(100):
        rec.add(float(v))
    p50, p90, p99 = rec.percentiles(50, 90, 99)
    assert p50 == rec.percentile(50)
    assert p90 == rec.percentile(90)
    assert p99 == rec.percentile(99)
    assert p50 <= p90 <= p99
    # the cached sort is invalidated by the next add
    rec.add(0.0)
    assert rec.percentile(0) == 0.0


def test_service_stats_carry_p90(run_file):
    path, _ = run_file
    with DataService(path, ServiceConfig(n_workers=2)) as svc:
        for _ in range(8):
            svc.submit("cli", WindowQuery(dataset=DS, rows=(0,))).result()
        st = svc.stats()
    assert st.p50_ms <= st.p90_ms <= st.p99_ms
    assert st.p90_ms > 0
    cs = st.clients["cli"]
    assert cs.p50_ms <= cs.p90_ms <= cs.p99_ms
