"""Graceful hypothesis degradation for property tests.

``hypothesis`` is an optional dependency: when present the property tests
run for real; when absent they *skip* instead of erroring the whole suite
at collection time.  Test modules import ``given``/``settings``/``st`` from
here instead of from ``hypothesis`` directly.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable stand-in: any attribute access / call / operator returns
        another stub, so module-level strategy expressions still evaluate."""

        def __getattr__(self, name: str) -> "_Strategy":
            return self

        def __call__(self, *args, **kwargs) -> "_Strategy":
            return self

        def __or__(self, other) -> "_Strategy":
            return self

        def map(self, fn) -> "_Strategy":
            return self

        def filter(self, fn) -> "_Strategy":
            return self

        def flatmap(self, fn) -> "_Strategy":
            return self

    st = _Strategy()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # Zero-arg replacement: pytest must not try to inject the
            # strategy kwargs as fixtures, so the original signature is
            # deliberately NOT preserved.
            def skipped():
                pytest.skip("hypothesis is not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate
