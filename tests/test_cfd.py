"""CFD substrate: space-tree layout, halo exchange, multigrid, projection,
snapshots in the paper layout, TRS branching, sliding window on CFD files."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd.multigrid import MGConfig, residual_norm, solve_poisson
from repro.cfd.projection import SOLID, FluidConfig, divergence, make_step
from repro.cfd.scenarios import add_cylinder, karman_vortex, operation_theatre
from repro.cfd.sim import Simulation
from repro.cfd.spacetree import TreeLayout, halo_exchange, to_blocked, to_composite, topology_arrays
from repro.core.checkpoint import CheckpointManager
from repro.core.sliding_window import TreeWindow


def test_blocked_composite_roundtrip():
    lay = TreeLayout(gx=3, gy=5, n=8, h=0.1)
    comp = jnp.arange(24 * 40, dtype=jnp.float32).reshape(24, 40)
    np.testing.assert_array_equal(np.asarray(to_composite(lay, to_blocked(lay, comp))), np.asarray(comp))


def test_halo_exchange_matches_composite_neighbours():
    lay = TreeLayout(gx=4, gy=4, n=4, h=1.0)
    comp = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)), jnp.float32)
    b = halo_exchange(lay, to_blocked(lay, comp))
    t = np.asarray(b).reshape(4, 4, 6, 6)
    c = np.asarray(comp)
    # grid (1,1): north halo row == composite row 3 (grid (0,1)'s last), cols 4:8
    np.testing.assert_array_equal(t[1, 1, 0, 1:-1], c[3, 4:8])
    # south halo == composite row 8 (grid (2,1)'s first)
    np.testing.assert_array_equal(t[1, 1, -1, 1:-1], c[8, 4:8])
    # west halo == composite col 3, east halo == composite col 8
    np.testing.assert_array_equal(t[1, 1, 1:-1, 0], c[4:8, 3])
    np.testing.assert_array_equal(t[1, 1, 1:-1, -1], c[4:8, 8])


def test_topology_arrays_morton_ranks():
    lay = TreeLayout(gx=4, gy=4, n=4, h=1.0)
    uids, subgrid, boxes, rank_of = topology_arrays(lay, n_ranks=4)
    assert uids.shape == (16,) and boxes.shape == (16, 4)
    # each rank gets a contiguous Morton chunk of 4 grids
    counts = np.bincount(rank_of, minlength=4)
    np.testing.assert_array_equal(counts, [4, 4, 4, 4])
    from repro.core import uid

    ranks, locals_, depths, _ = uid.unpack_array(uids)
    assert set(ranks.tolist()) == {0, 1, 2, 3}
    assert (depths == 0).all()


def test_multigrid_converges():
    """V-cycles contract the residual on a manufactured Poisson problem."""
    n = 64
    h = 1.0 / n
    x = (jnp.arange(n) + 0.5) * h
    X, Y = jnp.meshgrid(x, x, indexing="ij")
    rhs = jnp.sin(np.pi * X) * jnp.sin(np.pi * Y)
    p2 = solve_poisson(rhs, h, MGConfig(), cycles=2)
    p6 = solve_poisson(rhs, h, MGConfig(), cycles=6)
    r0 = float(jnp.sqrt(jnp.mean(rhs**2)))
    r2 = float(residual_norm(p2, rhs, h))
    r6 = float(residual_norm(p6, rhs, h))
    assert r2 < 0.6 * r0, (r0, r2)
    assert r6 < 0.05 * r0, (r0, r6)
    # per-cycle contraction is mesh-size independent (the multigrid claim)
    assert r6 < 0.35 * r2


def test_projection_reduces_divergence():
    cfg, state = karman_vortex(nx=32, ny=64)
    step = make_step(cfg)
    for _ in range(5):
        state = step(state)
    div = divergence(state["u"], state["v"], cfg.h)
    fluid = np.asarray(state["cell_type"]) == 0
    # interior divergence small relative to the velocity scale / h
    assert float(jnp.abs(jnp.where(jnp.asarray(fluid), div, 0.0)).mean()) < 0.5
    for f in ("u", "v", "p"):
        assert bool(jnp.isfinite(state[f]).all()), f


def test_karman_flow_deflects_around_cylinder():
    cfg, state = karman_vortex(nx=32, ny=64)
    step = make_step(cfg)
    for _ in range(30):
        state = step(state)
    ct = np.asarray(state["cell_type"])
    u = np.asarray(state["u"])
    assert (u[ct == SOLID] == 0).all()  # no-slip inside the obstacle
    # flow accelerates around the cylinder row
    cyl_rows = np.where((ct == SOLID).any(axis=1))[0]
    gap = u[: cyl_rows.min(), :]
    assert gap.max() > cfg.u_in * 1.02


def test_thermal_scenario_heats_air():
    cfg, state = operation_theatre(nx=32, ny=32)
    step = make_step(cfg)
    T0 = float(state["T"].mean())
    for _ in range(20):
        state = step(state)
    assert bool(jnp.isfinite(state["T"]).all())
    assert float(state["T"].max()) > T0 + 0.5  # lamps inject heat


def test_snapshot_restart_bit_identical(tmp_path):
    cfg, state = karman_vortex(nx=32, ny=64)
    mgr = CheckpointManager(str(tmp_path / "run.th5"), common={"scenario": "karman"})
    sim = Simulation(cfg, state, mgr)
    sim.run(4)
    s0 = sim.snapshot()
    sim.run(3)
    after_direct = {f: np.asarray(sim.state[f]) for f in ("u", "v")}
    # restart from the snapshot and redo the same 3 steps
    sim.restore(s0)
    sim.run(3)
    for f in ("u", "v"):
        np.testing.assert_allclose(np.asarray(sim.state[f]), after_direct[f], atol=1e-6)
    mgr.close()


def test_trs_branching_karman(tmp_path):
    """Paper §4 scenario 1: roll back, move the obstacle, branches diverge."""
    cfg, state = karman_vortex(nx=32, ny=64)
    mgr = CheckpointManager(str(tmp_path / "root.th5"), common={"scenario": "karman"})
    sim = Simulation(cfg, state, mgr)
    sim.run(3)
    s1 = sim.snapshot()
    sim.run(3)
    sim.snapshot()

    ct2 = add_cylinder(np.asarray(sim.state["cell_type"]), cfg.nx, cfg.ny, cx=8, cy=40, d=6)
    branch = sim.branch(
        s1, str(tmp_path / "branch.th5"), overlay={"obstacle": "second-cylinder"},
        cell_type=jnp.asarray(ct2),
    )
    assert float(branch.state["t"]) == pytest.approx(s1 * cfg.dt, rel=1e-4)
    branch.run(3)
    base_u = np.asarray(sim.state["u"])
    br_u = np.asarray(branch.state["u"])
    assert np.abs(base_u - br_u).max() > 1e-3  # the steered branch diverged
    # lineage bookkeeping
    from repro.core.steering import BranchManager

    bm = BranchManager(branch.manager)
    assert bm.effective_config()["obstacle"] == "second-cylinder"
    assert s1 in bm.available_steps()
    mgr.close()
    branch.manager.close()


def test_sliding_window_on_cfd_snapshot(tmp_path):
    """Offline sliding window over a CFD snapshot file (paper §3.1)."""
    cfg, state = karman_vortex(nx=32, ny=64)
    mgr = CheckpointManager(str(tmp_path / "run.th5"))
    sim = Simulation(cfg, state, mgr)
    sim.run(1)
    step = sim.snapshot()
    group = f"/simulation/step_{step:08d}"
    tw = TreeWindow.from_file(mgr.file, group)
    # uniform level: every grid is a leaf; full-domain query returns the root
    sel = tw.select([0, 0], [10, 10], max_grids=1)
    assert sel == [0]
    # gather those rows from the cell-data dataset
    data = tw.gather(mgr.file, f"{group}/state/current_cell_data", sel)
    assert data.shape[0] == 1
    mgr.close()
